//! Continuous-batching decode scheduler — the multi-session serving
//! layer over the KV-cached decode API, where the paper's O(L)
//! attention actually earns its keep: a server for heavy traffic must
//! interleave prefill and decode across many concurrent generation
//! streams, not run one `DecodeSession` at a time.
//!
//! ## Paged KV memory
//!
//! Session KV state lives in fixed-size pool pages
//! ([`crate::tensor::PagePool`] / [`crate::tensor::PagedRows`]), not
//! per-session contiguous arenas. That changes the two things that used
//! to bound concurrency:
//!
//! * **Admission is page-accounted, not reservation-accounted.** In the
//!   default demand-grown mode a session is charged only for the
//!   context pages it has actually faulted (its layer-0/head-0 fine-K
//!   stream, ×`page_len`, is the designated "context tokens" measure),
//!   so `max_tokens` no longer pre-pays `max_new` tokens that may never
//!   be generated. Growth happens one page at a time per decode round;
//!   when the pool can't cover a round, the engine first drops
//!   prefix-cache entries (LRU), then evicts the **youngest** active
//!   session(s) and requeues their requests at the queue head — a
//!   deterministic out-of-pages policy that preserves FIFO order and,
//!   because every request re-runs from its own seeded RNG stream,
//!   never changes any request's tokens. `reserve = true` restores the
//!   PR-4 contiguous-reservation semantics (the baseline the serve
//!   bench compares against): the full `prompt + max_new` horizon is
//!   pre-faulted and charged at admission.
//! * **Prompt *prefixes* share pages.** A radix tree over prompt token
//!   sequences ([`super::radix::RadixCache`]) keeps the
//!   per-`(layer, head)` page tables of recent prefills. An admission
//!   walks the trie for the longest common prefix with any cached
//!   prompt and clones the covering pages (refcount bumps — no page
//!   copies), prefilling only the unmatched suffix, so the
//!   shared-system-prompt workload pays prefill for each distinct
//!   suffix instead of each full prompt and counts the shared pages
//!   **once** against `max_tokens`. How much of the match is shareable
//!   is the engine's call: fine K/V/Q pages split at any
//!   `page_len`-aligned cut the algorithm declares prefix-pure
//!   ([`crate::attention::Attention::prefix_share_align`] — any causal
//!   cut for `full`/`local`, completed-coarse-cell cuts for `h1d`,
//!   nothing for the length-dependent `lowrank`/`blocksparse`), while
//!   h1d pyramid pages are shared only for fully-completed coarse
//!   blocks, with boundary partials replayed from the shared fine
//!   pages (`DecodeState::clone_prefix_into`). An exact whole-prompt
//!   match stays a free hit for **every** algorithm, including the
//!   non-causal and length-dependent ones (prefill outputs are a pure
//!   function of the full prompt), and skips the forward pass outright.
//!   Shared pages are immutable: a session's first mutation of a
//!   boundary page copies it first, so only pages holding
//!   still-accumulating partials privatise.
//! * **Prefill is chunkable.** With `prefill_chunk > 0` a prefilling
//!   session runs its prompt through the trunk `prefill_chunk` tokens
//!   at a time, one chunk per tick interleaved with decode rounds —
//!   long-prompt arrivals stop stalling in-flight streams for a whole
//!   prompt's forward pass. Each chunk ends at a prefix-pure cut and
//!   resumes via the same partial-prefix machinery (a chunked prefill
//!   is a self-resume), so chunking never changes tokens; algorithms
//!   with no interior pure cuts prefill in one shot regardless.
//!
//! ## Scheduler state machine
//!
//! A request moves `pending → active → completed` through
//! [`ServeEngine::tick`], which runs one scheduling round:
//!
//!  1. **Admission** — while the head of the FIFO queue fits both
//!     budgets (`max_batch` concurrent sessions, `max_tokens` context
//!     pages), pop it, take a recycled slot from the session pool, and
//!     either clone the prefix-cache entry (hit) or run **one batched
//!     prefill forward** through the shared `ModelWorkspace` — the
//!     `run_trunk` observer bulk-loads every `(layer, head)`
//!     [`DecodeState`] — then sample the first token.
//!  2. **Growth staging** (demand-grown mode) — pre-fault every page
//!     this round's appends will touch (evicting as described above if
//!     the budget is exhausted), so worker-thread appends never take
//!     the pool lock.
//!  3. **Decode round** — every active session advances by one token
//!     through a ragged batched step: embeddings for all `n` sessions
//!     are assembled into `[n, D]` rows, each layer runs its LayerNorm
//!     / Q/K/V / output / FFN matmuls **once for the whole batch**, and
//!     attention goes through
//!     [`Attention::decode_step_batch`](crate::attention::Attention::decode_step_batch).
//!     With
//!     `threads > 1` the active set is split into contiguous chunks
//!     that run on the crate thread pool.
//!  4. **Completion / eviction** — sessions that reached their
//!     `max_new` emit a [`Completion`]; their pages return to the pool
//!     and their slot (page tables, token and logits buffers included)
//!     recycles for the next admission.
//!
//! ## Ragged-batch layout
//!
//! Active sessions sit at different context lengths; nothing is padded.
//! Session `i` contributes row `i` of every `[n, ·]` activation matrix,
//! and its per-`(layer, head)` `DecodeState`s advance independently.
//! Because every per-row computation is independent and loop orders
//! match the single-session step path (page tables change the layout of
//! the caches, never the values or read order), batched logits are
//! **bitwise** what a lone `DecodeSession` produces — `tests/serve.rs`
//! pins batched-vs-sequential parity at 1e-5 and determinism under
//! arrival-order permutations.
//!
//! ## Budget knobs ([`ServeConfig`])
//!
//! * `max_batch` — concurrent-session cap (compute bound per round);
//! * `max_tokens` — context-token budget: page-granular tokens of
//!   fine-K context actually allocated across sessions and cache,
//!   shared pages counted once (a request whose rounded-up
//!   `prompt + max_new` could never fit is rejected at
//!   [`ServeEngine::submit`]);
//! * `page_len` — rows per KV page (power of two);
//! * `reserve` — contiguous-reservation admission (the paged-off
//!   baseline; disables the prefix cache);
//! * `prefix_cache` — retained prompt-cache entries (0 disables);
//! * `prefill_chunk` — max prompt tokens prefilled per tick
//!   (0 = whole prompt at admission);
//! * `threads` — worker count for prefill head dispatch and chunked
//!   decode rounds (`<= 1` runs on the calling thread);
//! * `spec_draft` / `spec_k` — speculative decoding
//!   ([`super::spec`]): a draft sibling built from the target's own
//!   weights proposes up to `spec_k` tokens per round and the target
//!   verifies the whole proposal in one batched decode-semantics pass,
//!   so a round can emit up to `spec_k + 1` tokens per session.
//!   Accepted prefixes commit; rejected tails roll back through
//!   [`DecodeState::truncate_to`], returning their pages to the pool.
//!   Output is bitwise identical to non-speculative serving at any
//!   temperature, so every parity guard above — and eviction's
//!   regenerate-on-requeue contract — still holds. Each session
//!   carries a small draft KV cache (always F32, unbudgeted overhead
//!   outside `max_tokens`).
//!
//! Entry points: `htx serve-bench` (closed-loop synthetic workload,
//! paged vs reserved), `benches/serve.rs` (emits `BENCH_serve.json`,
//! the CI perf trajectory, including the shared-prefix paged points),
//! `examples/cpu_serve.rs`.

use std::collections::VecDeque;
use std::sync::Arc;
use std::time::Instant;

use super::config::AttnSpec;
use super::radix::{CachedPrefix, RadixCache};
use super::spec::{begin_draft, spec_round, SpecBufs, SpecDraft, SpecSlot, SpecTotals};
use super::{matmul_q, sample_logits, DecodeWorkspace, Model, ModelWorkspace, LN_EPS};
use crate::attention::DecodeState;
use crate::tensor::ops::{add_assign, add_bias_rows, gelu, layernorm_rows_into};
use crate::tensor::paged::DEFAULT_PAGE_LEN;
use crate::tensor::{Mat, PageDtype, PagePool, PoolStats};
use crate::util::bench::{derive_seed, synthetic_prompt};
use crate::util::Rng;

/// Scheduler budgets; see the module docs.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Maximum concurrently active sessions per round.
    pub max_batch: usize,
    /// Context-token budget: page-granular fine-K tokens allocated
    /// across active sessions and the prefix cache, shared pages
    /// counted once. In `reserve` mode the whole `prompt + max_new`
    /// horizon is charged at admission instead.
    pub max_tokens: usize,
    /// Rows per KV page (power of two). Smaller pages share prompt
    /// prefixes at finer granularity; larger pages amortise the page
    /// hop in the decode inner loop.
    pub page_len: usize,
    /// Pre-fault and charge the full `prompt + max_new` horizon at
    /// admission — the PR-4 contiguous-reservation baseline semantics
    /// (no demand growth, no eviction, prefix cache and chunked
    /// prefill disabled).
    pub reserve: bool,
    /// Retained prefix-cache entries (0 disables the cache; ignored in
    /// `reserve` mode).
    pub prefix_cache: usize,
    /// Maximum prompt tokens prefilled per tick. `0` prefills the
    /// whole (unshared) prompt at admission, the classic behaviour.
    /// Positive values interleave prefill chunks with decode rounds so
    /// a long-prompt arrival cannot stall in-flight streams for a
    /// whole forward pass; chunk boundaries land on the next
    /// prefix-pure cut at or after the nominal chunk end, so chunking
    /// never changes generated tokens. Algorithms with no interior
    /// pure cuts (`lowrank`/`blocksparse`, or any non-causal model)
    /// prefill in one shot regardless of this knob.
    pub prefill_chunk: usize,
    /// Worker threads for prefill and chunked decode rounds
    /// (`<= 1` means the calling thread).
    pub threads: usize,
    /// Storage dtype for every session's fine K/V pages. `F16`/`I8`
    /// pages hold the same `page_len` rows in fewer f32 slots, so each
    /// budgeted page charges proportionally fewer context tokens
    /// against `max_tokens` — compressed caches admit more concurrent
    /// sessions under the same budget, at bounded decode drift.
    pub kv_dtype: PageDtype,
    /// Streaming sliding-window budget in fine context tokens
    /// (0 = unbounded, the default). When a decoding session's fine
    /// history exceeds the window, each round ends by retiring the
    /// pages behind it back to the pool through
    /// [`crate::attention::Attention::decode_retire`]: `h1d` keeps its
    /// coarse pyramid levels as the far-field summary and releases the
    /// dead fine K/V/Q pages (plus completed coarse-band prefixes), so
    /// outputs stay **bitwise** the unwindowed session's while resident
    /// pages stay bounded; `local` keeps `max(radius, window)` fine
    /// rows; exact algorithms (`full`, `lowrank`, `blocksparse`) keep
    /// everything — their `decode_retire` is a no-op, because
    /// retirement would change their outputs. Incompatible with
    /// `reserve` (the contiguous baseline pre-pays its whole horizon)
    /// and with `spec_draft` (rollback replays fine history the window
    /// may have retired).
    pub window: usize,
    /// Speculative-decoding draft spec (`None` disables speculation).
    /// The draft model is built once, at engine construction, from the
    /// target's own weights ([`SpecDraft::build`]); every session then
    /// carries its own small draft KV cache (always F32, unbudgeted)
    /// alongside its target states. Greedy and sampled outputs stay
    /// bitwise identical to non-speculative serving. Requires a causal
    /// target; a pyramid (`h1d`) target additionally requires exact
    /// F32 `kv_dtype` pages — rollback replays the fine history into
    /// the boundary partials.
    pub spec_draft: Option<SpecDraft>,
    /// Maximum draft tokens proposed per speculative round; each round
    /// emits between 1 and `spec_k + 1` tokens per session. `0` with a
    /// configured draft degenerates to plain one-token rounds.
    pub spec_k: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        Self {
            max_batch: 8,
            max_tokens: usize::MAX,
            page_len: DEFAULT_PAGE_LEN,
            reserve: false,
            prefix_cache: 8,
            prefill_chunk: 0,
            threads: 1,
            kv_dtype: PageDtype::F32,
            window: 0,
            spec_draft: None,
            spec_k: 0,
        }
    }
}

/// One generation request: a prompt, a token budget and per-request
/// sampling parameters (greedy at `temperature <= 0`, otherwise a
/// seeded softmax draw — each request owns its RNG stream, so results
/// are independent of batch composition, and an evicted-and-requeued
/// request regenerates exactly the same tokens).
#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<u32>,
    /// Tokens to generate (>= 1); the first is sampled from the
    /// prefill logits, exactly like the sequential `htx generate` loop.
    pub max_new: usize,
    pub temperature: f32,
    pub seed: u64,
}

/// A finished request: the generated tokens plus the `[vocab]` logits
/// of the final generated position (the parity pin for tests).
#[derive(Clone, Debug)]
pub struct Completion {
    pub id: u64,
    pub prompt_len: usize,
    pub tokens: Vec<u32>,
    pub last_logits: Vec<f32>,
    /// Round index at which the request was admitted / finished. Once
    /// admitted a session produces one token per round, so these mark
    /// *when* the request held a slot; queueing delay before admission
    /// is visible engine-wide as rounds where `queued() > 0`. An
    /// evicted request reports its final (successful) admission.
    pub admitted_round: usize,
    pub finished_round: usize,
}

/// Aggregate serving metrics for one run.
#[derive(Clone, Debug, Default)]
pub struct ServeStats {
    /// Decode rounds executed.
    pub rounds: usize,
    /// Tokens generated (prefill-sampled first tokens included).
    pub generated: usize,
    /// Prompt tokens actually run through the prefill trunk. A
    /// whole-prompt cache hit prefills nothing; a partial-prefix hit
    /// prefills only the unshared suffix.
    pub prefill_tokens: usize,
    /// Prompt tokens *not* prefilled because a radix-cache prefix
    /// covered them (whole-prompt and partial hits both count) — the
    /// headline saving of the shared-system-prompt regime:
    /// `prefill_tokens + prefill_tokens_saved` is the workload's total
    /// prompt tokens.
    pub prefill_tokens_saved: usize,
    /// Total wall time across ticks (admission + rounds), seconds.
    pub wall_s: f64,
    /// Wall time of each decode round. Admission/prefill time is
    /// excluded (it shows up in `wall_s` and therefore throughput), so
    /// the p50/p95 derived from these samples measures the same thing
    /// as the sequential baseline's per-`step` samples.
    pub round_s: Vec<f64>,
    /// Wall time of each tick that ran a decode round, measured from
    /// after the admission loop: interleaved prefill chunks + growth
    /// staging + the round itself. Under chunked prefill this is the
    /// honest inter-token gap an in-flight stream observes (a decode
    /// token arrives once per tick), which `round_s` alone understates;
    /// indexed 1:1 with `round_tokens`.
    pub tick_s: Vec<f64>,
    /// Tokens produced by each round — the active sessions that round,
    /// or, under speculation, the sum of every session's emitted
    /// tokens (1..=`spec_k + 1` each).
    pub round_tokens: Vec<usize>,
    /// Peak concurrently active sessions.
    pub peak_active: usize,
    /// Prefix-cache lookups / hits (identical-prompt admissions that
    /// skipped the prefill forward entirely).
    pub prefix_lookups: usize,
    pub prefix_hits: usize,
    /// Sessions evicted and requeued by the out-of-pages policy.
    pub evictions: usize,
    /// Requests cancelled via [`ServeEngine::cancel`] (client
    /// disconnects); their pages were released and no [`Completion`]
    /// was emitted.
    pub cancelled: usize,
    /// Peak page-granular context tokens allocated (shared pages
    /// counted once) — what `max_tokens` bounds.
    pub peak_ctx_tokens: usize,
    /// Peak unique KV pages alive in the pool, all streams (fine K/V,
    /// Q history, pyramid levels).
    pub peak_pages: usize,
    /// Pages returned to the pool by the streaming window
    /// ([`ServeConfig::window`]) across all sessions — cumulative
    /// retirement volume; 0 when no window is configured or the
    /// algorithm retires nothing (`full`/`lowrank`/`blocksparse`).
    pub window_retired_pages: usize,
    /// Peak resident pages of any single decoding session (all its
    /// per-`(layer, head)` streams summed), sampled at the end of each
    /// round. With a window this stays bounded as contexts grow — the
    /// gauge the `--long` streaming bench asserts on; without one it
    /// tracks the longest context.
    pub peak_session_pages: usize,
    /// Speculative rounds executed — one per active session per decode
    /// round when a draft is configured. Work counters: rounds whose
    /// tokens were later discarded by an eviction still count (the
    /// requeued request re-runs them), so these measure speculation
    /// effort, while `generated` measures net tokens.
    pub spec_rounds: usize,
    /// Draft tokens proposed across all speculative rounds.
    pub draft_proposed: usize,
    /// Draft proposals the target accepted. Each round emits its
    /// accepted prefix plus one unconditional sample, so spec-round
    /// tokens total `draft_accepted + spec_rounds`.
    pub draft_accepted: usize,
}

impl ServeStats {
    /// Aggregate throughput: generated tokens per wall second.
    pub fn tokens_per_sec(&self) -> f64 {
        if self.wall_s > 0.0 {
            self.generated as f64 / self.wall_s
        } else {
            0.0
        }
    }

    /// Aggregate per-token cost in µs (`wall / generated`) — the
    /// regression-gate metric of `BENCH_serve.json`.
    pub fn per_token_us(&self) -> f64 {
        if self.generated > 0 {
            self.wall_s * 1e6 / self.generated as f64
        } else {
            0.0
        }
    }

    /// Per-token latency percentile in µs: every token generated in a
    /// round observes that round's wall time (`pct` in 0..=100).
    /// `None` when no decode round ran — a zero-completion run (every
    /// request rejected at admission, or a stats read before the first
    /// round) has no latency distribution to index into; the old
    /// `(samples.len() - 1)` rank math must never see that case.
    pub fn try_latency_us(&self, pct: f64) -> Option<f64> {
        let mut samples: Vec<f64> = Vec::new();
        for (s, n) in self.round_s.iter().zip(&self.round_tokens) {
            samples.extend(std::iter::repeat(*s * 1e6).take(*n));
        }
        if samples.is_empty() {
            return None;
        }
        samples.sort_by(|a, b| a.partial_cmp(b).expect("finite latencies"));
        let idx = ((pct.clamp(0.0, 100.0) / 100.0) * (samples.len() - 1) as f64).round() as usize;
        Some(samples[idx.min(samples.len() - 1)])
    }

    /// [`ServeStats::try_latency_us`] with the empty case reported as
    /// `0.0` — the `BENCH_serve.json` convention.
    pub fn latency_us(&self, pct: f64) -> f64 {
        self.try_latency_us(pct).unwrap_or(0.0)
    }

    /// Inter-token latency percentile in µs over whole ticks
    /// (`tick_s`): every token generated in a tick's round observes
    /// that tick's full wall time, including any prefill chunks
    /// interleaved before the round. The number chunked prefill must
    /// keep bounded when long prompts arrive mid-stream; `None` when
    /// no decode round ran.
    pub fn try_tick_latency_us(&self, pct: f64) -> Option<f64> {
        let mut samples: Vec<f64> = Vec::new();
        for (s, n) in self.tick_s.iter().zip(&self.round_tokens) {
            samples.extend(std::iter::repeat(*s * 1e6).take(*n));
        }
        if samples.is_empty() {
            return None;
        }
        samples.sort_by(|a, b| a.partial_cmp(b).expect("finite latencies"));
        let idx = ((pct.clamp(0.0, 100.0) / 100.0) * (samples.len() - 1) as f64).round() as usize;
        Some(samples[idx.min(samples.len() - 1)])
    }

    /// [`ServeStats::try_tick_latency_us`] with the empty case as `0.0`.
    pub fn tick_latency_us(&self, pct: f64) -> f64 {
        self.try_tick_latency_us(pct).unwrap_or(0.0)
    }

    /// Mean tokens per decode round — active sessions per round (batch
    /// fill) without speculation; with a draft configured, emitted
    /// tokens per round across the batch.
    pub fn mean_occupancy(&self) -> f64 {
        if self.round_tokens.is_empty() {
            0.0
        } else {
            self.round_tokens.iter().sum::<usize>() as f64 / self.round_tokens.len() as f64
        }
    }

    /// Fraction of admissions served from the prefix cache.
    pub fn prefix_hit_rate(&self) -> f64 {
        if self.prefix_lookups == 0 {
            0.0
        } else {
            self.prefix_hits as f64 / self.prefix_lookups as f64
        }
    }

    /// Fraction of draft proposals the target accepted (0 when the
    /// draft never proposed — speculation off or `spec_k == 0`).
    pub fn spec_acceptance_rate(&self) -> f64 {
        if self.draft_proposed == 0 {
            0.0
        } else {
            self.draft_accepted as f64 / self.draft_proposed as f64
        }
    }

    /// Tokens emitted per speculative round — the effective
    /// tokens-per-step of the target model (`> 1.0` is the speculation
    /// win; exactly 1.0 at `spec_k == 0` or with every proposal
    /// rejected).
    pub fn spec_tokens_per_step(&self) -> f64 {
        if self.spec_rounds == 0 {
            0.0
        } else {
            (self.draft_accepted + self.spec_rounds) as f64 / self.spec_rounds as f64
        }
    }
}

/// Completions plus run-level stats — returned by both
/// [`ServeEngine::run`] and the [`run_sequential`] baseline.
#[derive(Clone, Debug)]
pub struct ServeReport {
    pub completions: Vec<Completion>,
    pub stats: ServeStats,
}

impl ServeReport {
    /// Generated tokens keyed and sorted by request id — the
    /// scheduling-invariant view two runs of one workload must agree
    /// on. The parity guard shared by `htx serve-bench`,
    /// `benches/serve.rs` and the test suite: batching, chunking,
    /// paging, prefix sharing and eviction may change *when* a request
    /// runs, never *what* it generates.
    pub fn tokens_by_id(&self) -> Vec<(u64, &[u32])> {
        let mut out: Vec<(u64, &[u32])> = self
            .completions
            .iter()
            .map(|c| (c.id, c.tokens.as_slice()))
            .collect();
        out.sort_by_key(|(id, _)| *id);
        out
    }
}

/// One pooled session: the per-`(layer, head)` KV states plus request
/// bookkeeping. Slots recycle through the engine's free pool — page
/// tables, token and logits buffers are grow-only, so same-shape
/// re-admissions allocate nothing outside the page pool.
struct SessionSlot {
    id: u64,
    prompt_len: usize,
    max_new: usize,
    /// `prompt + max_new`, the session's context horizon (pages are
    /// faulted up to here on demand; fully pre-faulted in reserve
    /// mode).
    budget: usize,
    temperature: f32,
    rng: Rng,
    /// Tokens consumed so far = position the next fed token decodes at.
    pos: usize,
    /// Last sampled token, fed in the next round.
    next_token: u32,
    /// Generated tokens (capacity reserved to `max_new` at admission).
    tokens: Vec<u32>,
    /// `[vocab]` logits of the final generated position, filled at
    /// completion (capacity reserved at admission).
    logits: Vec<f32>,
    /// `layer * n_heads + head` order, like `DecodeWorkspace`.
    states: Vec<DecodeState>,
    /// Draft decode caches when speculation is on ([`begin_draft`]
    /// order — the draft's own layer/head count); empty otherwise.
    draft_states: Vec<DecodeState>,
    /// The original request, kept so an out-of-pages eviction can
    /// requeue it verbatim (and so chunked prefill can read the
    /// remaining prompt suffix).
    request: Option<Request>,
    /// Prompt tokens already in the states (cache-shared prefix plus
    /// prefilled chunks). A session decodes only once this reaches
    /// `prompt_len`; until then it sits in the engine's prefilling set.
    prefilled: usize,
    admitted_round: usize,
    done: bool,
}

impl SessionSlot {
    fn fresh() -> Self {
        Self {
            id: 0,
            prompt_len: 0,
            max_new: 0,
            budget: 0,
            temperature: 0.0,
            rng: Rng::new(0),
            pos: 0,
            next_token: 0,
            tokens: Vec::new(),
            logits: Vec::new(),
            states: Vec::new(),
            draft_states: Vec::new(),
            request: None,
            prefilled: 0,
            admitted_round: 0,
            done: false,
        }
    }
}

/// Per-worker activation buffers for one chunk of a decode round —
/// the `[n, ·]` counterpart of the `[1, ·]` buffers in
/// `DecodeWorkspace`. Grow-only, recycled round to round.
#[derive(Default)]
struct StepBuf {
    x: Mat,
    hn: Mat,
    q: Mat,
    k: Mat,
    v: Mat,
    merged: Mat,
    proj: Mat,
    ff: Mat,
    logits: Mat,
}

impl StepBuf {
    fn snapshot(&self) -> Vec<(usize, usize)> {
        [
            &self.x,
            &self.hn,
            &self.q,
            &self.k,
            &self.v,
            &self.merged,
            &self.proj,
            &self.ff,
            &self.logits,
        ]
        .iter()
        .map(|m| (m.data.as_ptr() as usize, m.data.capacity()))
        .collect()
    }
}

/// One ragged decode round over `slots`: embed every session's pending
/// token at its own position, run each layer's batched matmuls once for
/// the chunk, advance all per-head caches through
/// `Attention::decode_step_batch`, then sample each session's next
/// token from the batched logits. Row `i` is bitwise the
/// single-session step path (loop orders match; every per-row op reads
/// only row `i`; the paged caches were staged by the scheduler thread,
/// so appends here are lock-free).
///
/// KEEP IN SYNC with `DecodeSession::step` (decode.rs): this is that
/// layer schedule at `[n, D]` instead of `[1, D]`, differing only in
/// `decode_step_batch` vs per-head `decode_step`. Any change to the
/// block structure must land in both; `tests/serve.rs` pins the parity
/// at 1e-5 so drift fails loudly.
fn step_slots(model: &Model, slots: &mut [SessionSlot], buf: &mut StepBuf) {
    if slots.is_empty() {
        return;
    }
    let cfg = &model.cfg;
    let p = &model.params;
    let n = slots.len();
    let (d, n_heads) = (cfg.d_model, cfg.n_heads);
    let n_states = cfg.n_layers * n_heads;

    // token + positional embedding for every session's current position
    buf.x.reset_for_overwrite(n, d);
    for (i, slot) in slots.iter().enumerate() {
        debug_assert!(
            slot.states[..n_states].iter().all(|st| st.remaining() > 0),
            "session {} stepped beyond its reserved context",
            slot.id
        );
        let row = buf.x.row_mut(i);
        for ((o, e), ps) in row
            .iter_mut()
            .zip(p.embed.row(slot.next_token as usize))
            .zip(p.pos.row(slot.pos))
        {
            *o = e + ps;
        }
    }

    for (layer, lp) in p.layers.iter().enumerate() {
        let lq = model.layer_quant(layer);
        // pre-LN attention block at [n, D]; one weight read per matrix
        layernorm_rows_into(&buf.x, &lp.ln1_scale, &lp.ln1_bias, LN_EPS, &mut buf.hn);
        matmul_q(&buf.hn, &lp.wq, lq.map(|q| &q.wq), &mut buf.q);
        matmul_q(&buf.hn, &lp.wk, lq.map(|q| &q.wk), &mut buf.k);
        matmul_q(&buf.hn, &lp.wv, lq.map(|q| &q.wv), &mut buf.v);
        buf.merged.reset_for_overwrite(n, d);
        let mut layer_states: Vec<&mut [DecodeState]> = slots
            .iter_mut()
            .map(|s| &mut s.states[layer * n_heads..(layer + 1) * n_heads])
            .collect();
        model.algo.decode_step_batch(
            &mut layer_states,
            &buf.q,
            &buf.k,
            &buf.v,
            cfg.causal,
            &mut buf.merged,
        );
        matmul_q(&buf.merged, &lp.wo, lq.map(|q| &q.wo), &mut buf.proj);
        add_assign(&mut buf.x, &buf.proj);

        // pre-LN feed-forward block
        layernorm_rows_into(&buf.x, &lp.ln2_scale, &lp.ln2_bias, LN_EPS, &mut buf.hn);
        matmul_q(&buf.hn, &lp.ff_w1, lq.map(|q| &q.ff_w1), &mut buf.ff);
        add_bias_rows(&mut buf.ff, &lp.ff_b1);
        gelu(&mut buf.ff);
        matmul_q(&buf.ff, &lp.ff_w2, lq.map(|q| &q.ff_w2), &mut buf.proj);
        add_bias_rows(&mut buf.proj, &lp.ff_b2);
        add_assign(&mut buf.x, &buf.proj);
    }

    model.logits_into(&buf.x, &mut buf.hn, &mut buf.logits);
    for (i, slot) in slots.iter_mut().enumerate() {
        slot.pos += 1;
        let row = buf.logits.row(i);
        let t = sample_logits(row, slot.temperature, &mut slot.rng) as u32;
        slot.tokens.push(t);
        if slot.tokens.len() >= slot.max_new {
            slot.done = true;
            slot.logits.clear();
            slot.logits.extend_from_slice(row);
        } else {
            slot.next_token = t;
        }
    }
}

/// One speculative round for every session in `slots` — the
/// [`step_slots`] counterpart when a draft is configured. Each session
/// runs [`spec_round`]: the draft proposes up to `k` tokens, the
/// target verifies `pending + proposals` in one batched
/// decode-semantics pass, the accepted prefix commits and the rejected
/// tail rolls back to the pool. Within a session the verify pass
/// batches over proposal rows; across sessions the engine parallelises
/// by splitting the active set into worker chunks, exactly like the
/// plain round. Page faults here take the pool lock (appends are not
/// pre-staged beyond the first row — rejected speculative pages would
/// make eager staging wasteful), which the shared-pool mutex makes
/// safe from worker threads.
///
/// Emitted tokens extend `slot.tokens` and advance `slot.pos`, so
/// completion, retirement, streaming (`for_each_active`) and eviction
/// replay all behave as if the tokens had arrived one round at a time.
fn spec_step_slots(
    target: &Model,
    draft: &Model,
    k: usize,
    slots: &mut [SessionSlot],
    bufs: &mut SpecBufs,
) -> SpecTotals {
    let mut totals = SpecTotals::default();
    for slot in slots.iter_mut() {
        let req = slot.request.as_ref().expect("active slot keeps its request");
        let mut sslot = SpecSlot {
            prompt: &req.prompt,
            history: &slot.tokens,
            pos: slot.pos,
            max_emit: slot.max_new - slot.tokens.len(),
            temperature: slot.temperature,
            rng: &mut slot.rng,
            states: &mut slot.states,
            draft_states: &mut slot.draft_states,
        };
        let out = spec_round(target, draft, k, &mut sslot, bufs);
        totals.add(&out);
        slot.pos += out.emitted;
        slot.tokens.extend_from_slice(&bufs.emitted);
        if slot.tokens.len() >= slot.max_new {
            slot.done = true;
            slot.logits.clear();
            slot.logits.extend_from_slice(bufs.target.logits().row(out.accepted));
        } else {
            slot.next_token = *bufs.emitted.last().expect("a round emits at least one token");
        }
    }
    totals
}

/// The continuous-batching scheduler; see the module docs. Owns the
/// model through an `Arc` so chunked rounds can travel through the
/// thread pool's `'static` jobs.
pub struct ServeEngine {
    model: Arc<Model>,
    cfg: ServeConfig,
    /// Context tokens one budgeted fine-K page charges under
    /// `cfg.kv_dtype` (`page_len` for f32; fewer for f16/int8) — the
    /// conversion factor between page counts and the `max_tokens`
    /// budget, precomputed at construction.
    kv_page_cost: usize,
    /// Shared KV page pool for every session's caches and the prefix
    /// cache; its accounting drives admission and growth (module docs).
    pool: PagePool,
    /// Radix-tree prefix cache over prompt token sequences; entries
    /// hold page-sharing state snapshots, LRU-evicted by last hit.
    cache: RadixCache,
    /// Whether partial-prefix sharing and chunked-prefill resume apply
    /// at all: the model is causal, its algorithm admits interior
    /// prefix-pure cuts (`prefix_share_align` — true for
    /// `full`/`local`/`h1d`, false for the length-dependent
    /// `lowrank`/`blocksparse`) and the KV pages are exact (`F32`).
    /// Compressed pages would resume a suffix from *dequantised* prefix
    /// rows — a fresh prefill reads exact activations, so the resumed
    /// tokens could drift; sharing-incapable configurations still get
    /// bitwise exact whole-prompt hits.
    share_capable: bool,
    /// Sessions still running their prompt through the trunk in
    /// `prefill_chunk`-token pieces, admission order; they hold a
    /// `max_batch` slot but don't decode until the prompt completes.
    prefilling: Vec<SessionSlot>,
    /// Shared batched-forward arena for admission prefills; its
    /// attention pool doubles as the decode-round worker pool (one set
    /// of OS threads per engine — prefill and rounds never overlap).
    prefill: ModelWorkspace,
    /// `[1, ·]` admission head-logits path (first-token sampling).
    adm_x: Mat,
    adm_hn: Mat,
    adm_logits: Mat,
    pending: VecDeque<Request>,
    active: Vec<SessionSlot>,
    /// Session pool: retired slots waiting to be re-admitted.
    free: Vec<SessionSlot>,
    /// Reusable chunk containers for pooled rounds (one per worker).
    chunk_store: Vec<Vec<SessionSlot>>,
    /// Per-worker step buffers.
    bufs: Vec<StepBuf>,
    /// Draft model for speculative rounds, built at construction from
    /// `cfg.spec_draft` (`None` = plain one-token rounds).
    draft: Option<Arc<Model>>,
    /// Per-worker speculative scratch (verify + propose buffers).
    spec_bufs: Vec<SpecBufs>,
    completions: Vec<Completion>,
    stats: ServeStats,
}

impl ServeEngine {
    pub fn new(model: Arc<Model>, cfg: ServeConfig) -> Result<ServeEngine, String> {
        if cfg.max_batch == 0 {
            return Err("max_batch must be >= 1".to_string());
        }
        if cfg.max_tokens == 0 {
            return Err("max_tokens budget must be >= 1".to_string());
        }
        if cfg.page_len == 0 || !cfg.page_len.is_power_of_two() {
            return Err(format!(
                "page_len must be a power of two >= 1 (got {})",
                cfg.page_len
            ));
        }
        if cfg.window > 0 && cfg.reserve {
            return Err(
                "a streaming window needs demand-grown paging: reserve mode pre-pays \
                 the whole contiguous horizon, so there is nothing to retire"
                    .to_string(),
            );
        }
        if cfg.window > 0 && cfg.spec_draft.is_some() {
            return Err(
                "speculative decoding cannot run with a streaming window: rejected-tail \
                 rollback replays fine history the window may already have retired"
                    .to_string(),
            );
        }
        let threads = cfg.threads.max(1);
        let kv_page_cost = cfg.kv_dtype.page_ctx_cost(cfg.page_len, model.cfg.d_head());
        let cache_limit = if cfg.reserve { 0 } else { cfg.prefix_cache };
        let share_capable = model.cfg.causal
            && model.algo.prefix_share_align(model.cfg.max_len.max(2)) > 0
            && cfg.kv_dtype == PageDtype::F32;
        let draft = match &cfg.spec_draft {
            Some(spec) => {
                if !model.cfg.causal {
                    return Err(
                        "speculative decoding needs a causal target (draft-and-verify \
                         replays strictly left-to-right decode steps)"
                            .to_string(),
                    );
                }
                if matches!(model.cfg.attention, AttnSpec::H1d { .. })
                    && cfg.kv_dtype != PageDtype::F32
                {
                    return Err(
                        "speculative decoding on an h1d target needs exact F32 KV pages \
                         (kv_dtype): rollback replays the fine history into the pyramid \
                         boundary partials"
                            .to_string(),
                    );
                }
                Some(Arc::new(spec.build(&model)?))
            }
            None => None,
        };
        Ok(ServeEngine {
            kv_page_cost,
            pool: PagePool::new(cfg.page_len),
            cache: RadixCache::new(cache_limit),
            share_capable,
            prefilling: Vec::with_capacity(cfg.max_batch),
            prefill: ModelWorkspace::new(threads),
            adm_x: Mat::default(),
            adm_hn: Mat::default(),
            adm_logits: Mat::default(),
            pending: VecDeque::new(),
            active: Vec::with_capacity(cfg.max_batch),
            free: Vec::with_capacity(cfg.max_batch),
            chunk_store: (0..threads).map(|_| Vec::with_capacity(cfg.max_batch)).collect(),
            bufs: (0..threads).map(|_| StepBuf::default()).collect(),
            draft,
            spec_bufs: (0..threads).map(|_| SpecBufs::default()).collect(),
            completions: Vec::new(),
            stats: ServeStats::default(),
            model,
            cfg,
        })
    }

    /// Validate and enqueue a request (FIFO). Rejects requests that
    /// could never run: empty prompt, `max_new == 0`, token ids outside
    /// the vocabulary, an overflowing or over-`max_len` context
    /// horizon, or a page-rounded horizon exceeding the engine's
    /// `max_tokens` budget even when the session runs alone.
    pub fn submit(&mut self, req: Request) -> Result<(), String> {
        self.validate(&req)?;
        self.pending.push_back(req);
        Ok(())
    }

    /// The [`ServeEngine::submit`] admission checks, side-effect free.
    fn validate(&self, req: &Request) -> Result<(), String> {
        let mcfg = &self.model.cfg;
        if req.prompt.is_empty() {
            return Err(format!("request {}: empty prompt", req.id));
        }
        if req.max_new == 0 {
            return Err(format!("request {}: max_new must be >= 1", req.id));
        }
        let budget = req.prompt.len().checked_add(req.max_new).ok_or_else(|| {
            format!(
                "request {}: prompt length {} + max_new {} overflows the context horizon",
                req.id,
                req.prompt.len(),
                req.max_new
            )
        })?;
        if budget > mcfg.max_len {
            return Err(format!(
                "request {}: prompt {} + max_new {} exceeds model max_len {}",
                req.id,
                req.prompt.len(),
                req.max_new,
                mcfg.max_len
            ));
        }
        // page-granular: the horizon this session could grow to, alone
        // (each page charges kv_page_cost tokens — fewer when the KV
        // pages are compressed)
        let granular = budget
            .div_ceil(self.cfg.page_len)
            .saturating_mul(self.kv_page_cost);
        if granular > self.cfg.max_tokens {
            return Err(format!(
                "request {}: page-rounded context reservation {granular} exceeds the \
                 max_tokens budget {}",
                req.id, self.cfg.max_tokens
            ));
        }
        if let Some(&bad) = req.prompt.iter().find(|&&t| t as usize >= mcfg.vocab_size) {
            return Err(format!(
                "request {}: token id {bad} >= vocab {}",
                req.id, mcfg.vocab_size
            ));
        }
        Ok(())
    }

    /// Queued requests not yet admitted.
    pub fn queued(&self) -> usize {
        self.pending.len()
    }

    /// Sessions currently holding a slot: decoding plus (under chunked
    /// prefill) still prefilling their prompt.
    pub fn active_sessions(&self) -> usize {
        self.active.len() + self.prefilling.len()
    }

    /// Run-so-far metrics (reset by [`ServeEngine::run`]).
    pub fn stats(&self) -> &ServeStats {
        &self.stats
    }

    /// Page-pool accounting right now (live/free/budgeted pages).
    pub fn pool_stats(&self) -> PoolStats {
        self.pool.stats()
    }

    /// Prefix-cache entries currently retained.
    pub fn prefix_cache_len(&self) -> usize {
        self.cache.len()
    }

    /// Prompt tokens currently covered by prefix-cache entries (token
    /// measure of the trie, pages may overlap between entries).
    pub fn prefix_cache_tokens(&self) -> usize {
        self.cache.cached_tokens()
    }

    /// Completions accumulated so far (drains the internal buffer).
    pub fn take_completions(&mut self) -> Vec<Completion> {
        std::mem::take(&mut self.completions)
    }

    /// Visit every active session's generated-so-far tokens. The net
    /// front end calls this after each [`ServeEngine::tick`] to stream
    /// newly generated tokens; callers keep their own per-request
    /// watermark, so an out-of-pages eviction (which clears and later
    /// regenerates bitwise-identical tokens) simply pauses the stream
    /// instead of double-sending.
    pub fn for_each_active(&self, mut f: impl FnMut(u64, &[u32])) {
        for slot in self.active.iter().chain(self.prefilling.iter()) {
            f(slot.id, &slot.tokens);
        }
    }

    /// Cancel a request by id — a client disconnect mid-stream. A
    /// pending request is dropped from the queue; an active session is
    /// torn down in place: its pages return to the pool, its generated
    /// tokens come off the `generated` count (they were never
    /// delivered) and **no** [`Completion`] is emitted. The slot
    /// recycles through the session pool exactly like a retirement, so
    /// cancellation leaks nothing — `capacity_snapshot` is invariant
    /// across a cancel + same-shape re-admission. Returns whether the
    /// id was found (pending or active).
    pub fn cancel(&mut self, id: u64) -> bool {
        if let Some(i) = self.pending.iter().position(|r| r.id == id) {
            self.pending.remove(i);
            self.stats.cancelled += 1;
            return true;
        }
        let found = if let Some(i) = self.active.iter().position(|s| s.id == id) {
            Some(self.active.remove(i))
        } else {
            self.prefilling
                .iter()
                .position(|s| s.id == id)
                .map(|i| self.prefilling.remove(i))
        };
        if let Some(mut slot) = found {
            slot.request = None;
            self.stats.generated -= slot.tokens.len();
            slot.tokens.clear();
            slot.logits.clear();
            for st in slot.states.iter_mut().chain(slot.draft_states.iter_mut()) {
                st.release_pages();
            }
            self.free.push(slot);
            self.stats.cancelled += 1;
            return true;
        }
        false
    }

    fn cache_limit(&self) -> usize {
        self.cache.limit()
    }

    /// Whether `extra_tokens` more context tokens fit `max_tokens`
    /// (tokens are dtype-weighted: the pool tracks each budgeted page
    /// at its `ctx_cost`, so compressed pages count for less).
    fn fits_ctx(&self, extra_tokens: usize) -> bool {
        if self.cfg.max_tokens == usize::MAX {
            return true;
        }
        self.pool.stats().ctx_tokens().saturating_add(extra_tokens) <= self.cfg.max_tokens
    }

    /// Largest cut `<= lcp` that is both `page_len`-aligned and
    /// algorithm-pure — the tokens a partial-prefix hit may actually
    /// share. Page alignment makes the fine-page split copy-free and
    /// keeps the page-count accounting exact; purity
    /// ([`crate::attention::Attention::prefix_share_align`]) guarantees
    /// the cached rows are bitwise what a fresh prefill of the new
    /// prompt would produce up to the cut. The two constraints are
    /// interleaved to a fixpoint: aligning can break purity and vice
    /// versa, but each step only shrinks `p`, so the loop terminates
    /// (at worst at 0).
    fn share_len(&self, lcp: usize) -> usize {
        let pl = self.cfg.page_len;
        let mut p = lcp & !(pl - 1);
        loop {
            let b = self.model.algo.prefix_share_align(p) & !(pl - 1);
            if b == p {
                return p;
            }
            p = b;
        }
    }

    /// [`ServeEngine::share_len`] capped to leave at least one suffix
    /// token: the admission path always runs a real forward over the
    /// tail to produce the first-token logits (only an *exact*
    /// whole-prompt hit skips the trunk, via the cached residual row).
    fn partial_share_len(&self, lcp: usize, prompt_len: usize) -> usize {
        self.share_len(lcp.min(prompt_len.saturating_sub(1)))
    }

    /// Context tokens admitting `req` would charge right now. A free
    /// whole-prompt hit is predicted only when the trie holds an entry
    /// for exactly this prompt *and* the engine forces the fine-Q
    /// history on (sharing-capable algorithms) — then `admit`'s hit
    /// path is guaranteed to take it, pyramid depth notwithstanding
    /// (deeper levels replay from the cached fine rows). Otherwise the
    /// unshared suffix — the whole prompt for sharing-incapable
    /// algorithms, which may still hit opportunistically — is charged
    /// conservatively, so the context budget can never be exceeded by
    /// a predicted-hit-turned-miss.
    fn admission_ctx_tokens(&self, req: &Request) -> usize {
        let pl = self.cfg.page_len;
        if self.cfg.reserve {
            return (req.prompt.len() + req.max_new)
                .div_ceil(pl)
                .saturating_mul(self.kv_page_cost);
        }
        let mut pages = req.prompt.len().div_ceil(pl);
        if self.cache_limit() > 0 && self.share_capable {
            if let Some((lcp, entry_len)) = self.cache.predict(&req.prompt) {
                if lcp == req.prompt.len() && entry_len == lcp {
                    return 0;
                }
                // shared pages are already counted in the pool (the
                // entry holds them); the session is charged only its
                // unshared suffix pages
                pages -= self.partial_share_len(lcp, req.prompt.len()) / pl;
            }
        }
        pages.saturating_mul(self.kv_page_cost)
    }

    /// Context tokens the outstanding chunks of prefilling sessions
    /// will still fault. Admission and growth keep this charged on top
    /// of the pool's live count, so interleaved chunk appends can never
    /// overrun `max_tokens` mid-prompt.
    fn prefill_debt(&self) -> usize {
        let pl = self.cfg.page_len;
        self.prefilling
            .iter()
            .map(|s| (s.prompt_len.div_ceil(pl) - s.prefilled.div_ceil(pl)) * self.kv_page_cost)
            .sum()
    }

    /// End of the prefill chunk starting at `from`: the nominal
    /// `prefill_chunk` tokens, extended to the next algorithm-pure cut
    /// so the next chunk's resume sees bitwise-correct cached rows.
    /// (Chunk cuts need purity only, not page alignment — nothing is
    /// shared across states at a chunk boundary.) The final chunk ends
    /// at the prompt itself, pure or not: nothing resumes after it.
    fn next_chunk_end(&self, from: usize, prompt_len: usize) -> usize {
        let mut e = (from + self.cfg.prefill_chunk).min(prompt_len);
        while e < prompt_len && self.model.algo.prefix_share_align(e) != e {
            e += 1;
        }
        e
    }

    fn cache_insert(&mut self, prompt: &[u32], states: &[DecodeState], last_x: &[f32]) {
        self.cache.insert(
            prompt,
            CachedPrefix {
                len: prompt.len(),
                states: states.iter().map(|s| s.snapshot_shared()).collect(),
                last_x: last_x.to_vec(),
            },
        );
    }

    /// `(pointer, capacity)` of every workspace buffer the engine owns
    /// — session slots (active and pooled) with their page tables and
    /// pages, prefix-cache entries, step buffers, the prefill arena,
    /// the admission head path and the page pool's free list plus its
    /// total-pages marker. Sorted, so the snapshot is invariant to
    /// slots migrating between the active set and the pool and to
    /// pages migrating between sessions, the cache and the free list;
    /// equal snapshots across ticks prove the steady state allocates
    /// nothing in any workspace **and grows the page pool by zero
    /// pages** (request outputs — completion token/logit copies — are
    /// not workspace and are excluded).
    pub fn capacity_snapshot(&self) -> Vec<(usize, usize)> {
        let mut out: Vec<(usize, usize)> = Vec::new();
        for slot in self
            .active
            .iter()
            .chain(self.prefilling.iter())
            .chain(self.free.iter())
        {
            out.push((slot.states.as_ptr() as usize, slot.states.capacity()));
            out.push((slot.draft_states.as_ptr() as usize, slot.draft_states.capacity()));
            for st in slot.states.iter().chain(slot.draft_states.iter()) {
                out.extend(st.buffer_snapshot());
            }
            out.push((slot.tokens.as_ptr() as usize, slot.tokens.capacity()));
            out.push((slot.logits.as_ptr() as usize, slot.logits.capacity()));
        }
        self.cache.buffer_snapshot_into(&mut out);
        for b in &self.bufs {
            out.extend(b.snapshot());
        }
        for b in &self.spec_bufs {
            out.extend(b.capacity_snapshot());
        }
        for c in &self.chunk_store {
            out.push((c.as_ptr() as usize, c.capacity()));
        }
        out.extend(self.pool.capacity_snapshot());
        out.extend(self.prefill.capacity_snapshot());
        for m in [&self.adm_x, &self.adm_hn, &self.adm_logits] {
            out.push((m.data.as_ptr() as usize, m.data.capacity()));
        }
        out.sort_unstable();
        out
    }

    /// Admit one request into a (recycled) session slot: wire its
    /// per-`(layer, head)` states to the shared page pool, walk the
    /// radix cache — an exact whole-prompt entry clones every page and
    /// skips the forward pass; a partial-prefix entry (sharing-capable
    /// algorithms) donates its aligned pure prefix — then prefill the
    /// unmatched suffix (inline, or staged into the chunked-prefill
    /// set) and sample the first token from the prompt's final logits.
    /// A request whose `max_new` is 1 completes here and never enters
    /// a decode round.
    ///
    /// KEEP IN SYNC with `Model::prefill_with` (decode.rs): same
    /// state-begin + `run_trunk` observer sequence, pooled instead of
    /// per-`DecodeWorkspace` (the one semantic difference: states are
    /// reserved to the request horizon, not `max_len` — h1d's step
    /// output is invariant to the extra pyramid depth).
    fn admit(&mut self, req: Request) {
        let model = Arc::clone(&self.model);
        let mcfg = &model.cfg;
        let n_heads = mcfg.n_heads;
        let d_model = mcfg.d_model;
        let n_states = mcfg.n_layers * n_heads;
        let mut slot = self.free.pop().unwrap_or_else(SessionSlot::fresh);
        slot.id = req.id;
        slot.prompt_len = req.prompt.len();
        slot.max_new = req.max_new;
        slot.budget = req.prompt.len() + req.max_new;
        slot.temperature = req.temperature;
        slot.rng = Rng::new(req.seed);
        slot.pos = req.prompt.len();
        slot.tokens.clear();
        slot.tokens.reserve(req.max_new);
        slot.logits.clear();
        slot.logits.reserve(mcfg.vocab_size);
        slot.admitted_round = self.stats.rounds;
        slot.prefilled = 0;
        slot.done = false;
        while slot.states.len() < n_states {
            slot.states.push(DecodeState::default());
        }
        for st in &mut slot.states[..n_states] {
            st.attach_pool(&self.pool, self.cfg.reserve);
            st.set_kv_dtype(self.cfg.kv_dtype);
        }
        // layer-0/head-0 fine K is the budgeted "context tokens" stream
        slot.states[0].mark_ctx_stream();
        for st in &mut slot.states[..n_states] {
            model.algo.decode_begin(st, slot.budget, mcfg.d_head());
        }
        // partial-prefix resume and chunked prefill both rebuild /
        // gather from the fine Q history, so sharing-eligible sessions
        // must keep it (full/local/h1d `decode_begin` default it off —
        // their decode step never reads fine Q rows)
        if self.share_capable
            && !self.cfg.reserve
            && (self.cache_limit() > 0 || self.cfg.prefill_chunk > 0)
        {
            for st in &mut slot.states[..n_states] {
                st.force_q_cache();
            }
        }
        // speculation: pyramid targets must keep the fine-Q history so
        // rejected tails can rebuild boundary partials on rollback, and
        // every session carries its own (small, unbudgeted) draft KV
        if let Some(draft) = &self.draft {
            for st in &mut slot.states[..n_states] {
                if st.n_coarse > 0 && !st.cache_q {
                    st.force_q_cache();
                }
            }
            begin_draft(draft, &mut slot.draft_states, &self.pool);
        }

        // radix cache: exact whole-prompt entries clone every page
        // (boundary partials included — bitwise) and skip the trunk;
        // partial hits donate their aligned pure prefix pages and
        // leave only the suffix to prefill
        let mut p0 = 0usize; // prompt tokens already in the states
        let mut exact = false;
        if self.cache_limit() > 0 {
            self.stats.prefix_lookups += 1;
            if let Some(hit) = self.cache.lookup(&req.prompt) {
                let dst_coarse = slot.states[0].n_coarse;
                if hit.lcp == req.prompt.len()
                    && hit.entry_len == hit.lcp
                    && (hit.cache_q || hit.n_coarse >= dst_coarse)
                {
                    // whole-prompt hit (any algorithm): a pyramid
                    // deeper than the entry's rebuilds from the cached
                    // fine Q rows inside clone_prefix_into
                    for (st, cst) in slot.states[..n_states].iter_mut().zip(&hit.states) {
                        cst.clone_prefix_into(st, hit.lcp);
                    }
                    self.adm_x.reset_for_overwrite(1, d_model);
                    self.adm_x.row_mut(0).copy_from_slice(&hit.last_x);
                    self.stats.prefix_hits += 1;
                    self.stats.prefill_tokens_saved += req.prompt.len();
                    p0 = req.prompt.len();
                    exact = true;
                } else if self.share_capable && hit.cache_q {
                    let p = self.partial_share_len(hit.lcp, req.prompt.len());
                    if p > 0 {
                        for (st, cst) in slot.states[..n_states].iter_mut().zip(&hit.states) {
                            cst.clone_prefix_into(st, p);
                        }
                        self.stats.prefix_hits += 1;
                        self.stats.prefill_tokens_saved += p;
                        p0 = p;
                    }
                }
            }
        }

        if !exact {
            // chunked prefill: a suffix longer than one chunk runs
            // through the trunk across later ticks, interleaved with
            // decode rounds (sharing-capable algorithms only — the
            // resume needs pure cuts)
            let suffix_len = req.prompt.len() - p0;
            if self.cfg.prefill_chunk > 0
                && self.share_capable
                && !self.cfg.reserve
                && suffix_len > self.cfg.prefill_chunk
            {
                slot.prefilled = p0;
                slot.request = Some(req);
                self.prefilling.push(slot);
                self.stats.peak_active = self
                    .stats
                    .peak_active
                    .max(self.active.len() + self.prefilling.len());
                return;
            }
            // inline prefill of the whole (remaining) prompt: one
            // batched forward; the observer bulk-loads every
            // (layer, head) cache — the decode.rs prefill, pooled
            if p0 == 0 {
                let states = &mut slot.states;
                model.run_trunk(&mut self.prefill, &req.prompt, 1, |layer, qkv| {
                    for h in 0..n_heads {
                        model.algo.decode_load_prefix(
                            &mut states[layer * n_heads + h],
                            qkv.q.head(h),
                            qkv.k.head(h),
                            qkv.v.head(h),
                        );
                    }
                });
            } else {
                model.run_trunk_resume(
                    &mut self.prefill,
                    &req.prompt[p0..],
                    &mut slot.states[..n_states],
                );
            }
            self.stats.prefill_tokens += suffix_len;
            self.adm_x.reset_for_overwrite(1, d_model);
            self.adm_x
                .row_mut(0)
                .copy_from_slice(self.prefill.x.row(suffix_len - 1));
            if self.cache_limit() > 0 {
                let last_x = self.adm_x.row(0).to_vec();
                self.cache_insert(&req.prompt, &slot.states[..n_states], &last_x);
            }
        }

        slot.prefilled = req.prompt.len();
        slot.request = Some(req);
        self.sample_first_token(slot);
    }

    /// Shared admission tail: head logits from the prompt's final
    /// residual row (already in `adm_x`), sample the first token, and
    /// route the session into the decode set — or straight to
    /// completion at `max_new == 1`, which never enters a round.
    fn sample_first_token(&mut self, mut slot: SessionSlot) {
        let model = Arc::clone(&self.model);
        model.logits_into(&self.adm_x, &mut self.adm_hn, &mut self.adm_logits);
        let row = self.adm_logits.row(0);
        let t = sample_logits(row, slot.temperature, &mut slot.rng) as u32;
        slot.tokens.push(t);
        self.stats.generated += 1;
        if slot.tokens.len() >= slot.max_new {
            slot.done = true;
            slot.logits.clear();
            slot.logits.extend_from_slice(row);
            // the session held a slot during its prefill even though it
            // never enters a decode round — count it as active
            self.stats.peak_active = self
                .stats
                .peak_active
                .max(self.active.len() + self.prefilling.len() + 1);
            self.retire(slot);
        } else {
            slot.next_token = t;
            self.active.push(slot);
            self.stats.peak_active = self
                .stats
                .peak_active
                .max(self.active.len() + self.prefilling.len());
        }
    }

    /// Advance every prefilling session by one prompt chunk (admission
    /// order). Chunks end at the next pure cut
    /// ([`ServeEngine::next_chunk_end`]); the next chunk resumes from
    /// the session's own cached rows (`Model::run_trunk_resume` — a
    /// self-resume, so chunking never changes tokens). A session whose
    /// prompt completes stores the prefix in the radix cache, samples
    /// its first token from the final residual row and joins the
    /// decode set.
    fn advance_prefill_chunks(&mut self, n_states: usize) {
        let model = Arc::clone(&self.model);
        let n_heads = model.cfg.n_heads;
        let d_model = model.cfg.d_model;
        let mut i = 0;
        while i < self.prefilling.len() {
            let (from, plen) = {
                let s = &self.prefilling[i];
                (s.prefilled, s.prompt_len)
            };
            let to = self.next_chunk_end(from, plen);
            {
                let slot = &mut self.prefilling[i];
                let req = slot.request.as_ref().expect("prefilling slot keeps its request");
                let chunk = &req.prompt[from..to];
                if from == 0 {
                    // first chunk of an unshared prompt: positions
                    // 0..to are a whole-prompt prefill of length `to`
                    let states = &mut slot.states;
                    model.run_trunk(&mut self.prefill, chunk, 1, |layer, qkv| {
                        for h in 0..n_heads {
                            model.algo.decode_load_prefix(
                                &mut states[layer * n_heads + h],
                                qkv.q.head(h),
                                qkv.k.head(h),
                                qkv.v.head(h),
                            );
                        }
                    });
                } else {
                    model.run_trunk_resume(&mut self.prefill, chunk, &mut slot.states[..n_states]);
                }
                slot.prefilled = to;
            }
            self.stats.prefill_tokens += to - from;
            if to < plen {
                i += 1;
                continue;
            }
            // prompt complete: cache it, sample the first token
            let slot = self.prefilling.remove(i);
            self.adm_x.reset_for_overwrite(1, d_model);
            self.adm_x
                .row_mut(0)
                .copy_from_slice(self.prefill.x.row(to - from - 1));
            if self.cache_limit() > 0 {
                let last_x = self.adm_x.row(0).to_vec();
                let req = slot.request.as_ref().expect("prefilling slot keeps its request");
                let prompt = &req.prompt;
                self.cache.insert(
                    prompt,
                    CachedPrefix {
                        len: prompt.len(),
                        states: slot.states[..n_states]
                            .iter()
                            .map(|s| s.snapshot_shared())
                            .collect(),
                        last_x,
                    },
                );
            }
            self.sample_first_token(slot);
        }
    }

    /// Out-of-pages eviction: release the slot's pages, requeue its
    /// request at the queue head (it re-runs from its own RNG stream,
    /// regenerating identical tokens) and recycle the slot.
    fn evict_requeue(&mut self, mut slot: SessionSlot) {
        let req = slot.request.take().expect("evicted slot keeps its request");
        for st in slot.states.iter_mut().chain(slot.draft_states.iter_mut()) {
            st.release_pages();
        }
        // the discarded tokens will be regenerated after the requeue,
        // so they come off the generated count
        self.stats.generated -= slot.tokens.len();
        slot.tokens.clear();
        slot.logits.clear();
        self.pending.push_front(req);
        self.free.push(slot);
        self.stats.evictions += 1;
    }

    /// Emit a [`Completion`], return the slot's pages to the pool and
    /// recycle the slot. Page tables and token/logit buffers keep
    /// their capacity, so a same-shape re-admission allocates nothing
    /// outside the (warm) page pool.
    fn retire(&mut self, mut slot: SessionSlot) {
        self.completions.push(Completion {
            id: slot.id,
            prompt_len: slot.prompt_len,
            tokens: slot.tokens.clone(),
            last_logits: slot.logits.clone(),
            admitted_round: slot.admitted_round,
            finished_round: self.stats.rounds,
        });
        slot.tokens.clear();
        slot.logits.clear();
        slot.request = None;
        for st in slot.states.iter_mut().chain(slot.draft_states.iter_mut()) {
            st.release_pages();
        }
        self.free.push(slot);
    }

    /// One scheduling round: admit what fits, advance one prefill
    /// chunk per prefilling session, stage this round's page growth
    /// (evicting under pressure), run one ragged decode round over the
    /// active set, retire finished sessions. Returns whether work
    /// remains (pending, prefilling or active requests).
    pub fn tick(&mut self) -> bool {
        let t0 = Instant::now();
        let n_states = self.model.cfg.n_layers * self.model.cfg.n_heads;

        // admission: head-of-line FIFO within the batch and context
        // budgets (outstanding chunk debt stays charged); under page
        // pressure the LRU cache entries go first
        loop {
            if self.active.len() + self.prefilling.len() >= self.cfg.max_batch {
                break;
            }
            let needed = match self.pending.front() {
                None => break,
                Some(r) => self.admission_ctx_tokens(r),
            };
            if !self.fits_ctx(needed.saturating_add(self.prefill_debt())) {
                if self.cache.evict_lru() {
                    continue;
                }
                break;
            }
            let req = self.pending.pop_front().expect("checked front");
            self.admit(req);
        }

        // tick clock: everything from here until the round completes
        // is what an in-flight stream waits through for its next token
        // (tick_s); admission prefills above land in wall_s only
        let t_tick = Instant::now();

        // interleaved chunked prefill: one chunk per prefilling
        // session; finished prompts join the decode set this round
        if !self.prefilling.is_empty() {
            self.advance_prefill_chunks(n_states);
        }

        // demand-grown rounds: pre-fault every page this round's
        // appends will touch, so worker-thread appends are lock-free.
        // Out of pages → drop cache entries (LRU), then evict
        // still-prefilling sessions, then the youngest decoding
        // session(s), requeueing each at the queue head — older
        // decoding sessions never lose their slot, and a requeued
        // request regenerates identical tokens from its own RNG stream.
        if !self.cfg.reserve && !self.active.is_empty() {
            // a speculative round may append up to spec_k + 1 tokens per
            // session (the worst case commits everything); charge that
            // horizon so a round can never overrun max_tokens mid-verify
            let spec_k = if self.draft.is_some() { self.cfg.spec_k } else { 0 };
            loop {
                let need: usize = self
                    .active
                    .iter()
                    .map(|s| {
                        let j = (spec_k + 1).min(s.max_new - s.tokens.len());
                        s.states[0].ctx_append_cost(j) * self.kv_page_cost
                    })
                    .sum::<usize>()
                    .saturating_add(self.prefill_debt());
                if self.fits_ctx(need) {
                    break;
                }
                if self.cache.evict_lru() {
                    continue;
                }
                if let Some(slot) = self.prefilling.pop() {
                    self.evict_requeue(slot);
                    continue;
                }
                if self.active.len() <= 1 {
                    // a lone session always fits: validate() bounds its
                    // page-rounded horizon by max_tokens
                    break;
                }
                let slot = self.active.pop().expect("non-empty active set");
                self.evict_requeue(slot);
            }
            for slot in &mut self.active {
                for st in &mut slot.states[..n_states] {
                    st.stage_append();
                }
            }
        }
        let ps = self.pool.stats();
        self.stats.peak_ctx_tokens = self.stats.peak_ctx_tokens.max(ps.ctx_tokens());
        self.stats.peak_pages = self.stats.peak_pages.max(ps.live);

        // one ragged decode round across every active session; timed on
        // its own so the latency percentiles measure the same thing as
        // the sequential baseline's per-step samples (admission/prefill
        // time lands in wall_s and throughput, not in round latency)
        let n = self.active.len();
        if n > 0 {
            let t_round = Instant::now();
            let round_tokens = if let Some(draft) = self.draft.clone() {
                // speculative round: every session drafts + verifies,
                // emitting 1..=spec_k + 1 tokens; worker-chunk split
                // identical to the plain round below
                let k = self.cfg.spec_k;
                let totals = match self.prefill.attn.pool() {
                    Some(pool) if n > 1 => {
                        let workers = pool.size().min(n);
                        let mut jobs: Vec<(Vec<SessionSlot>, SpecBufs)> =
                            Vec::with_capacity(workers);
                        for c in (0..workers).rev() {
                            let lo = c * n / workers;
                            let mut chunk = self.chunk_store.pop().expect("chunk container");
                            chunk.clear();
                            chunk.extend(self.active.drain(lo..));
                            let buf = self.spec_bufs.pop().expect("spec buffer");
                            jobs.push((chunk, buf));
                        }
                        jobs.reverse();
                        let model = Arc::clone(&self.model);
                        let done = pool.map(jobs, move |(mut chunk, mut buf)| {
                            let t = spec_step_slots(
                                model.as_ref(),
                                draft.as_ref(),
                                k,
                                &mut chunk,
                                &mut buf,
                            );
                            (chunk, buf, t)
                        });
                        let mut totals = SpecTotals::default();
                        for (mut chunk, buf, t) in done {
                            self.active.append(&mut chunk);
                            self.chunk_store.push(chunk);
                            self.spec_bufs.push(buf);
                            totals.merge(&t);
                        }
                        totals
                    }
                    _ => spec_step_slots(
                        self.model.as_ref(),
                        draft.as_ref(),
                        k,
                        &mut self.active,
                        &mut self.spec_bufs[0],
                    ),
                };
                self.stats.spec_rounds += totals.rounds as usize;
                self.stats.draft_proposed += totals.proposed as usize;
                self.stats.draft_accepted += totals.accepted as usize;
                totals.emitted as usize
            } else {
                match self.prefill.attn.pool() {
                    Some(pool) if n > 1 => {
                        let workers = pool.size().min(n);
                        // deterministic contiguous split: chunk c covers
                        // active rows [c*n/workers, (c+1)*n/workers)
                        let mut jobs: Vec<(Vec<SessionSlot>, StepBuf)> =
                            Vec::with_capacity(workers);
                        for c in (0..workers).rev() {
                            let lo = c * n / workers;
                            let mut chunk = self.chunk_store.pop().expect("chunk container");
                            chunk.clear();
                            chunk.extend(self.active.drain(lo..));
                            let buf = self.bufs.pop().expect("step buffer");
                            jobs.push((chunk, buf));
                        }
                        jobs.reverse();
                        let model = Arc::clone(&self.model);
                        let done = pool.map(jobs, move |(mut chunk, mut buf)| {
                            step_slots(model.as_ref(), &mut chunk, &mut buf);
                            (chunk, buf)
                        });
                        for (mut chunk, buf) in done {
                            self.active.append(&mut chunk);
                            self.chunk_store.push(chunk);
                            self.bufs.push(buf);
                        }
                    }
                    _ => {
                        step_slots(self.model.as_ref(), &mut self.active, &mut self.bufs[0]);
                    }
                }
                n
            };
            self.stats.rounds += 1;
            self.stats.generated += round_tokens;
            self.stats.round_tokens.push(round_tokens);
            self.stats.round_s.push(t_round.elapsed().as_secs_f64());
            self.stats.tick_s.push(t_tick.elapsed().as_secs_f64());
            // eviction: retire finished sessions, preserving order
            let mut i = 0;
            while i < self.active.len() {
                if self.active[i].done {
                    let slot = self.active.remove(i);
                    self.retire(slot);
                } else {
                    i += 1;
                }
            }
            // streaming window: behind-the-window fine pages go back to
            // the pool, page-granular and output-exact (h1d keeps its
            // coarse pyramid as the far-field summary; exact algorithms
            // retire nothing)
            if self.cfg.window > 0 {
                let window = self.cfg.window;
                for slot in &mut self.active {
                    for st in &mut slot.states[..n_states] {
                        self.stats.window_retired_pages +=
                            self.model.algo.decode_retire(st, window);
                    }
                }
            }
            let mut peak = 0usize;
            for slot in &self.active {
                peak = peak.max(slot.states[..n_states].iter().map(|s| s.resident_pages()).sum());
            }
            self.stats.peak_session_pages = self.stats.peak_session_pages.max(peak);
        }
        self.stats.wall_s += t0.elapsed().as_secs_f64();
        !self.active.is_empty() || !self.prefilling.is_empty() || !self.pending.is_empty()
    }

    /// Submit every request and tick until the queue drains; returns
    /// the completions plus run stats (and resets both for the next
    /// run — the engine, its session pool, page pool and prefix cache
    /// are reusable). The whole batch is validated before anything is
    /// enqueued, so a rejected request leaves the engine exactly as it
    /// was — no half-queued workload leaking into the next run.
    pub fn run(&mut self, requests: Vec<Request>) -> Result<ServeReport, String> {
        for r in &requests {
            self.validate(r)?;
        }
        for r in requests {
            self.pending.push_back(r);
        }
        while self.tick() {}
        Ok(ServeReport {
            completions: std::mem::take(&mut self.completions),
            stats: std::mem::take(&mut self.stats),
        })
    }
}

/// The sequential baseline the serve acceptance compares against: one
/// session at a time through `Model::prefill_with` / `step`, recycling
/// a single `DecodeWorkspace` — identical request semantics and report
/// shape, so it doubles as the parity oracle for `tests/serve.rs`.
pub fn run_sequential(model: &Model, requests: &[Request]) -> Result<ServeReport, String> {
    run_sequential_dtype(model, requests, PageDtype::F32)
}

/// [`run_sequential`] with the sessions' KV pages stored as `kv_dtype`
/// — the one-at-a-time oracle for the engine's compressed-cache modes
/// (`htx serve-bench --kv-dtype` uses it as the parity reference).
pub fn run_sequential_dtype(
    model: &Model,
    requests: &[Request],
    kv_dtype: PageDtype,
) -> Result<ServeReport, String> {
    let mut ws = DecodeWorkspace::serial();
    ws.set_kv_dtype(kv_dtype);
    let mut completions = Vec::with_capacity(requests.len());
    let mut stats = ServeStats::default();
    let t_all = Instant::now();
    for req in requests {
        if req.max_new == 0 {
            return Err(format!("request {}: max_new must be >= 1", req.id));
        }
        let horizon = req.prompt.len().checked_add(req.max_new).ok_or_else(|| {
            format!("request {}: prompt + max_new overflows the context horizon", req.id)
        })?;
        if horizon > model.cfg.max_len {
            return Err(format!(
                "request {}: prompt {} + max_new {} exceeds model max_len {}",
                req.id,
                req.prompt.len(),
                req.max_new,
                model.cfg.max_len
            ));
        }
        let mut rng = Rng::new(req.seed);
        let mut session = model.prefill_with(ws, &req.prompt)?;
        stats.prefill_tokens += req.prompt.len();
        let mut tokens = Vec::with_capacity(req.max_new);
        let first = sample_logits(session.logits().row(0), req.temperature, &mut rng) as u32;
        tokens.push(first);
        stats.generated += 1;
        let mut next = first;
        let last_logits: Vec<f32> = if tokens.len() >= req.max_new {
            session.logits().row(0).to_vec()
        } else {
            loop {
                let ts = Instant::now();
                let logits = session.step(next)?;
                stats.round_s.push(ts.elapsed().as_secs_f64());
                stats.round_tokens.push(1);
                stats.rounds += 1;
                let t = sample_logits(logits.row(0), req.temperature, &mut rng) as u32;
                tokens.push(t);
                stats.generated += 1;
                if tokens.len() >= req.max_new {
                    break logits.row(0).to_vec();
                }
                next = t;
            }
        };
        completions.push(Completion {
            id: req.id,
            prompt_len: req.prompt.len(),
            tokens,
            last_logits,
            admitted_round: 0,
            finished_round: stats.rounds,
        });
        stats.peak_active = 1;
        ws = session.into_workspace();
    }
    stats.wall_s = t_all.elapsed().as_secs_f64();
    Ok(ServeReport { completions, stats })
}

/// Closed-loop synthetic workload: `n` requests whose prompt lengths
/// cycle through `prompt_mix`, sharing `max_new` and `temperature`,
/// with per-request RNG seeds derived from `seed`. All requests are
/// queued up front; admission paces them — the next stream starts as
/// soon as budget frees (the closed-loop serving regime). Prompt
/// tokens come from `util::bench::synthetic_prompt`, the generator
/// shared with the decode bench and `htx serve-bench`.
pub fn synthetic_workload(
    n: usize,
    prompt_mix: &[usize],
    max_new: usize,
    vocab: usize,
    temperature: f32,
    seed: u64,
) -> Vec<Request> {
    assert!(!prompt_mix.is_empty(), "prompt_mix must name at least one length");
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|i| {
            let pl = prompt_mix[i % prompt_mix.len()];
            Request {
                id: i as u64,
                prompt: synthetic_prompt(pl, vocab, &mut rng),
                max_new,
                temperature,
                seed: derive_seed(seed, i as u64),
            }
        })
        .collect()
}

/// Shared-system-prompt workload: `n` requests with one identical
/// `prompt_len`-token prompt (per-request RNG streams still distinct) —
/// the regime the prefix cache turns into an O(1)-per-duplicate
/// prefill with prompt pages allocated once.
pub fn shared_prefix_workload(
    n: usize,
    prompt_len: usize,
    max_new: usize,
    vocab: usize,
    temperature: f32,
    seed: u64,
) -> Vec<Request> {
    let mut rng = Rng::new(seed);
    let prompt = synthetic_prompt(prompt_len, vocab, &mut rng);
    (0..n)
        .map(|i| Request {
            id: i as u64,
            prompt: prompt.clone(),
            max_new,
            temperature,
            seed: derive_seed(seed, i as u64),
        })
        .collect()
}

/// Multi-tenant workload: every request opens with one shared
/// `system_len`-token system prompt and continues with its own
/// `suffix_len` distinct tokens — the regime the radix cache turns
/// into one system-prompt prefill plus per-request suffix prefills,
/// with the shared pages allocated (and budgeted) once.
pub fn multi_tenant_workload(
    n: usize,
    system_len: usize,
    suffix_len: usize,
    max_new: usize,
    vocab: usize,
    temperature: f32,
    seed: u64,
) -> Vec<Request> {
    let mut rng = Rng::new(seed);
    let system = synthetic_prompt(system_len, vocab, &mut rng);
    (0..n)
        .map(|i| {
            let mut prompt = system.clone();
            prompt.extend(synthetic_prompt(suffix_len, vocab, &mut rng));
            Request {
                id: i as u64,
                prompt,
                max_new,
                temperature,
                seed: derive_seed(seed, i as u64),
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{AttnSpec, ModelConfig};

    fn tiny_model(attention: AttnSpec, max_len: usize) -> Model {
        Model::new(
            ModelConfig {
                vocab_size: 29,
                d_model: 16,
                n_heads: 2,
                n_layers: 2,
                d_ff: 24,
                max_len,
                causal: true,
                attention,
                quant_weights: false,
            },
            7,
        )
        .unwrap()
    }

    #[test]
    fn compressed_kv_pages_admit_more_concurrent_sessions() {
        // the f32 shape of tight_token_budget_serialises_admissions:
        // each request grows to 4 pages; at page_len 4 and d_head 8 an
        // f32 page charges 4 tokens (16 per session — a 20-token budget
        // serialises), while an f16 page packs its 4x8 rows into 16
        // slots = 2 tokens (8 per session — two sessions fit)
        let model = Arc::new(tiny_model(AttnSpec::Full, 24));
        let mk = |kv_dtype| ServeConfig {
            max_batch: 4,
            max_tokens: 20,
            page_len: 4,
            threads: 1,
            kv_dtype,
            ..ServeConfig::default()
        };
        let reqs = synthetic_workload(4, &[9], 5, 29, 0.0, 3);
        let mut exact = ServeEngine::new(Arc::clone(&model), mk(PageDtype::F32)).unwrap();
        let rf = exact.run(reqs.clone()).unwrap();
        assert_eq!(rf.stats.peak_active, 1, "f32 baseline must serialise");
        let mut packed = ServeEngine::new(Arc::clone(&model), mk(PageDtype::F16)).unwrap();
        let rh = packed.run(reqs.clone()).unwrap();
        assert!(
            rh.stats.peak_active >= 2,
            "f16 KV should at least double concurrency, got {}",
            rh.stats.peak_active
        );
        assert!(rh.stats.peak_ctx_tokens <= 20, "budget exceeded");
        assert_eq!(rh.completions.len(), 4);
        // batched f16 decode matches the one-at-a-time f16 oracle
        let seq = run_sequential_dtype(&model, &reqs, PageDtype::F16).unwrap();
        assert_eq!(seq.tokens_by_id(), rh.tokens_by_id());
    }

    #[test]
    fn int8_kv_and_quantised_weights_still_serve() {
        // the lossiest configuration end to end: int8 KV pages plus
        // int8 weights, batched engine vs sequential oracle
        let model = Arc::new(
            Model::new(
                ModelConfig {
                    vocab_size: 29,
                    d_model: 16,
                    n_heads: 2,
                    n_layers: 2,
                    d_ff: 24,
                    max_len: 24,
                    causal: true,
                    attention: AttnSpec::H1d { nr: 4 },
                    quant_weights: true,
                },
                7,
            )
            .unwrap(),
        );
        let cfg = ServeConfig {
            max_batch: 3,
            kv_dtype: PageDtype::I8,
            threads: 1,
            ..ServeConfig::default()
        };
        let reqs = synthetic_workload(5, &[6, 9], 4, 29, 0.0, 21);
        let mut eng = ServeEngine::new(Arc::clone(&model), cfg).unwrap();
        let rep = eng.run(reqs.clone()).unwrap();
        assert_eq!(rep.completions.len(), 5);
        assert!(rep
            .completions
            .iter()
            .all(|c| c.last_logits.iter().all(|x| x.is_finite())));
        let seq = run_sequential_dtype(&model, &reqs, PageDtype::I8).unwrap();
        assert_eq!(seq.tokens_by_id(), rep.tokens_by_id());
    }

    #[test]
    fn submit_rejects_unrunnable_requests() {
        let model = Arc::new(tiny_model(AttnSpec::Full, 16));
        let mut eng = ServeEngine::new(
            Arc::clone(&model),
            ServeConfig {
                max_batch: 2,
                max_tokens: 32,
                threads: 1,
                ..ServeConfig::default()
            },
        )
        .unwrap();
        let ok = Request {
            id: 0,
            prompt: vec![1, 2, 3],
            max_new: 4,
            temperature: 0.0,
            seed: 1,
        };
        eng.submit(ok.clone()).unwrap();
        let mut bad = ok.clone();
        bad.prompt.clear();
        assert!(eng.submit(bad).unwrap_err().contains("empty prompt"));
        let mut bad = ok.clone();
        bad.max_new = 0;
        assert!(eng.submit(bad).unwrap_err().contains("max_new"));
        let mut bad = ok.clone();
        bad.max_new = 14; // 3 + 14 > max_len 16
        assert!(eng.submit(bad).unwrap_err().contains("max_len"));
        let mut bad = ok.clone();
        bad.prompt = vec![1; 18]; // longer than max_len outright
        assert!(eng.submit(bad).unwrap_err().contains("max_len"));
        let mut bad = ok.clone();
        bad.prompt = vec![0, 29]; // token id outside the vocabulary
        assert!(eng.submit(bad).unwrap_err().contains("vocab"));
        // prompt + max_new overflowing usize is rejected, not wrapped
        let mut bad = ok.clone();
        bad.max_new = usize::MAX;
        assert!(eng.submit(bad).unwrap_err().contains("overflows"));
        // a reservation within max_len but beyond the engine's whole
        // max_tokens budget can never be admitted: rejected at submit
        let mut eng2 = ServeEngine::new(
            model,
            ServeConfig {
                max_batch: 2,
                max_tokens: 6,
                page_len: 4,
                threads: 1,
                ..ServeConfig::default()
            },
        )
        .unwrap();
        assert!(eng2.submit(ok).unwrap_err().contains("max_tokens"));
    }

    #[test]
    fn engine_rejects_bad_page_len() {
        let model = Arc::new(tiny_model(AttnSpec::Full, 16));
        for bad in [0usize, 6, 12] {
            let err = ServeEngine::new(
                Arc::clone(&model),
                ServeConfig {
                    page_len: bad,
                    ..ServeConfig::default()
                },
            );
            assert!(err.is_err(), "page_len {bad} must be rejected");
        }
    }

    #[test]
    fn run_rejects_batches_atomically() {
        let model = Arc::new(tiny_model(AttnSpec::Full, 16));
        let mut eng = ServeEngine::new(Arc::clone(&model), ServeConfig::default()).unwrap();
        let mut reqs = synthetic_workload(3, &[4], 3, 29, 0.0, 1);
        reqs[2].prompt = vec![99]; // out-of-vocab, rejected at validation
        assert!(eng.run(reqs).is_err());
        assert_eq!(eng.queued(), 0, "a rejected batch must not enqueue anything");
        // the engine is still clean: a valid batch then runs normally
        let rep = eng.run(synthetic_workload(3, &[4], 3, 29, 0.0, 1)).unwrap();
        assert_eq!(rep.completions.len(), 3);
    }

    #[test]
    fn max_new_one_completes_at_prefill_without_a_round() {
        let model = Arc::new(tiny_model(AttnSpec::H1d { nr: 4 }, 16));
        let mut eng = ServeEngine::new(Arc::clone(&model), ServeConfig::default()).unwrap();
        let reqs = vec![Request {
            id: 9,
            prompt: vec![1, 2, 3, 4],
            max_new: 1,
            temperature: 0.0,
            seed: 5,
        }];
        let rep = eng.run(reqs.clone()).unwrap();
        assert_eq!(rep.stats.rounds, 0);
        assert_eq!(rep.stats.peak_active, 1, "prefill-only sessions still held a slot");
        assert_eq!(rep.completions.len(), 1);
        assert_eq!(rep.completions[0].tokens.len(), 1);
        // matches the sequential loop exactly
        let seq = run_sequential(&model, &reqs).unwrap();
        assert_eq!(seq.completions[0].tokens, rep.completions[0].tokens);
        assert_eq!(seq.completions[0].last_logits, rep.completions[0].last_logits);
    }

    #[test]
    fn tight_token_budget_serialises_admissions() {
        let model = Arc::new(tiny_model(AttnSpec::Full, 24));
        // each request can grow to ceil(14/4)*4 = 16 context tokens; a
        // 20-token budget fits one session at a time (two would need
        // >= 24), so the budget serialises the batch
        let mut eng = ServeEngine::new(
            model,
            ServeConfig {
                max_batch: 4,
                max_tokens: 20,
                page_len: 4,
                threads: 1,
                ..ServeConfig::default()
            },
        )
        .unwrap();
        let reqs = synthetic_workload(4, &[9], 5, 29, 0.0, 3);
        let rep = eng.run(reqs).unwrap();
        assert_eq!(rep.completions.len(), 4);
        assert_eq!(rep.stats.peak_active, 1, "budget should serialise sessions");
        assert_eq!(rep.stats.generated, 4 * 5);
        assert!(rep.stats.peak_ctx_tokens <= 20, "budget exceeded");
    }

    #[test]
    fn reserved_mode_matches_paged_results() {
        // the contiguous-reservation baseline and the paged engine are
        // the same scheduler over different memory policies: identical
        // workload results, different admission pacing
        let model = Arc::new(tiny_model(AttnSpec::H1d { nr: 4 }, 32));
        let reqs = synthetic_workload(6, &[7, 12], 6, 29, 0.0, 9);
        let mut paged = ServeEngine::new(Arc::clone(&model), ServeConfig::default()).unwrap();
        let mut reserved = ServeEngine::new(
            Arc::clone(&model),
            ServeConfig {
                reserve: true,
                ..ServeConfig::default()
            },
        )
        .unwrap();
        let rp = paged.run(reqs.clone()).unwrap();
        let rr = reserved.run(reqs).unwrap();
        assert_eq!(rp.tokens_by_id(), rr.tokens_by_id());
        assert_eq!(rr.stats.prefix_lookups, 0, "reserve mode disables the cache");
    }

    #[test]
    fn windowed_serving_matches_unwindowed_and_retires_pages() {
        // streaming window: h1d retirement is output-exact (the coarse
        // pyramid keeps the far field), so a windowed run's tokens are
        // bitwise the unwindowed engine's and the sequential oracle's —
        // while dead fine pages stream back to the pool mid-generation
        let model = Arc::new(tiny_model(AttnSpec::H1d { nr: 2 }, 96));
        let mk = |window: usize| ServeConfig {
            max_batch: 2,
            page_len: 4,
            threads: 1,
            window,
            ..ServeConfig::default()
        };
        let reqs = synthetic_workload(3, &[7, 12], 48, 29, 0.0, 61);
        let mut plain = ServeEngine::new(Arc::clone(&model), mk(0)).unwrap();
        let rp = plain.run(reqs.clone()).unwrap();
        let mut windowed = ServeEngine::new(Arc::clone(&model), mk(16)).unwrap();
        let rw = windowed.run(reqs.clone()).unwrap();
        assert_eq!(rp.tokens_by_id(), rw.tokens_by_id(), "the window changed tokens");
        let seq = run_sequential(&model, &reqs).unwrap();
        assert_eq!(seq.tokens_by_id(), rw.tokens_by_id());
        assert_eq!(rp.stats.window_retired_pages, 0, "no window, no retirement");
        assert!(rw.stats.window_retired_pages > 0, "long streams must retire pages");
        assert!(
            rw.stats.peak_session_pages < rp.stats.peak_session_pages,
            "windowed sessions must hold fewer resident pages: {} vs {}",
            rw.stats.peak_session_pages,
            rp.stats.peak_session_pages
        );
    }

    #[test]
    fn window_config_gates_surface_at_construction() {
        let model = Arc::new(tiny_model(AttnSpec::H1d { nr: 4 }, 24));
        // reserve mode pre-pays its contiguous horizon: nothing to retire
        let err = ServeEngine::new(
            Arc::clone(&model),
            ServeConfig {
                window: 8,
                reserve: true,
                ..ServeConfig::default()
            },
        )
        .err()
        .expect("window + reserve must be rejected");
        assert!(err.contains("reserve"), "{err}");
        // speculation rolls back through fine history the window retires
        let err = ServeEngine::new(
            model,
            ServeConfig {
                window: 8,
                ..spec_cfg("local:2,layers:1", 2, 1)
            },
        )
        .err()
        .expect("window + speculation must be rejected");
        assert!(err.contains("window"), "{err}");
    }

    #[test]
    fn deeper_horizon_same_prompt_rebuilds_the_pyramid_and_still_hits() {
        // an entry cached at a shallow pyramid serves a deeper-horizon
        // twin exactly: the forced fine-Q history lets the hit path
        // rebuild the extra coarse levels by replay inside
        // clone_prefix_into, so the admission predictor may promise
        // the free hit (budget stays sound) and no twin re-prefills
        let model = Arc::new(tiny_model(AttnSpec::H1d { nr: 2 }, 28));
        let mut eng = ServeEngine::new(
            Arc::clone(&model),
            ServeConfig {
                max_batch: 2,
                // roomy enough that no eviction interferes: the pin here
                // is the predictor/hit-path agreement, not page pressure
                max_tokens: 48,
                page_len: 4,
                threads: 1,
                ..ServeConfig::default()
            },
        )
        .unwrap();
        let prompt: Vec<u32> = (0..6).map(|t| (t % 7) as u32).collect();
        let a = Request {
            id: 0,
            prompt: prompt.clone(),
            max_new: 2,
            temperature: 0.0,
            seed: 3,
        };
        // horizon 20 vs 8: decode_coarse_levels grows with the horizon,
        // so b needs a deeper pyramid than a's cached entry carries
        let b = Request {
            id: 1,
            prompt: prompt.clone(),
            max_new: 14,
            temperature: 0.0,
            seed: 4,
        };
        // same prompt and horizon as b: hits the same shallow entry
        let c = Request {
            id: 2,
            prompt: prompt.clone(),
            max_new: 14,
            temperature: 0.0,
            seed: 5,
        };
        let reqs = vec![a, b, c];
        let rep = eng.run(reqs.clone()).unwrap();
        assert_eq!(rep.completions.len(), 3);
        assert_eq!(
            rep.stats.prefix_hits, 2,
            "both twins hit, horizon depth notwithstanding"
        );
        assert_eq!(rep.stats.prefill_tokens, 6, "only the first admission prefills");
        assert_eq!(rep.stats.prefill_tokens_saved, 2 * 6);
        assert_eq!(rep.stats.evictions, 0);
        assert!(
            rep.stats.peak_ctx_tokens <= 48,
            "predicted hits must keep the budget: peak {}",
            rep.stats.peak_ctx_tokens
        );
        let seq = run_sequential(&model, &reqs).unwrap();
        assert_eq!(seq.tokens_by_id(), rep.tokens_by_id());
    }

    #[test]
    fn partial_prefix_hit_prefills_only_the_suffix() {
        // multi-tenant regime: one 8-token system prompt, distinct
        // 4-token user suffixes. At page_len 4 and h1d nr 2 the cut at
        // 8 is page-aligned and prefix-pure, so admissions 2..4 clone
        // the system-prompt pages and prefill 4 tokens instead of 12 —
        // with tokens bitwise what unshared sequential decoding yields
        let model = Arc::new(tiny_model(AttnSpec::H1d { nr: 2 }, 32));
        let mut eng = ServeEngine::new(
            Arc::clone(&model),
            ServeConfig {
                max_batch: 4,
                page_len: 4,
                threads: 1,
                ..ServeConfig::default()
            },
        )
        .unwrap();
        let reqs = multi_tenant_workload(4, 8, 4, 4, 29, 0.0, 11);
        assert!(reqs.iter().all(|r| r.prompt.len() == 12));
        assert!(reqs[1..].iter().all(|r| r.prompt[..8] == reqs[0].prompt[..8]));
        let rep = eng.run(reqs.clone()).unwrap();
        assert_eq!(rep.completions.len(), 4);
        assert_eq!(rep.stats.prefix_hits, 3, "every follower shares the system prompt");
        assert_eq!(
            rep.stats.prefill_tokens + rep.stats.prefill_tokens_saved,
            4 * 12,
            "prefilled + saved must cover the workload's prompt tokens"
        );
        assert_eq!(rep.stats.prefill_tokens_saved, 3 * 8);
        assert_eq!(
            rep.stats.prefill_tokens,
            12 + 3 * 4,
            "followers prefill only their suffix"
        );
        // >= 2x prefill-token saving, the acceptance bar
        assert!(rep.stats.prefill_tokens * 2 <= 4 * 12);
        let seq = run_sequential(&model, &reqs).unwrap();
        assert_eq!(seq.tokens_by_id(), rep.tokens_by_id());
    }

    #[test]
    fn partial_sharing_skips_sharing_incapable_algorithms() {
        // blocksparse's length-seeded random key sets leave no
        // prefix-pure cuts (prefix_share_align == 0): partial hits must
        // not be taken, but exact whole-prompt duplicates still hit
        let model = Arc::new(tiny_model(
            AttnSpec::BlockSparse {
                window: 2,
                n_global: 1,
                n_random: 1,
                seed: 9,
            },
            32,
        ));
        let mut eng = ServeEngine::new(
            Arc::clone(&model),
            ServeConfig {
                max_batch: 4,
                page_len: 4,
                threads: 1,
                ..ServeConfig::default()
            },
        )
        .unwrap();
        let mut reqs = multi_tenant_workload(3, 8, 4, 3, 29, 0.0, 13);
        // request 3 duplicates request 2's whole prompt
        reqs[2].prompt = reqs[1].prompt.clone();
        let rep = eng.run(reqs.clone()).unwrap();
        assert_eq!(rep.completions.len(), 3);
        assert_eq!(
            rep.stats.prefix_hits, 1,
            "only the exact duplicate may hit a non-causal-pure algorithm"
        );
        assert_eq!(rep.stats.prefill_tokens, 2 * 12);
        assert_eq!(rep.stats.prefill_tokens_saved, 12);
        let seq = run_sequential(&model, &reqs).unwrap();
        assert_eq!(seq.tokens_by_id(), rep.tokens_by_id());
    }

    #[test]
    fn chunked_prefill_is_token_identical_and_samples_tick_latency() {
        // chunk boundaries land on pure cuts and resume from the
        // session's own cached rows, so chunking changes scheduling
        // only: tokens must be bitwise the unchunked engine's (and the
        // sequential oracle's), and every decode round gains a tick_s
        // sample covering the interleaved chunk work
        let model = Arc::new(tiny_model(AttnSpec::H1d { nr: 2 }, 64));
        let mk = |chunk: usize| ServeConfig {
            max_batch: 3,
            page_len: 4,
            prefill_chunk: chunk,
            threads: 1,
            ..ServeConfig::default()
        };
        let reqs = synthetic_workload(3, &[20, 24], 6, 29, 0.0, 19);
        let mut whole = ServeEngine::new(Arc::clone(&model), mk(0)).unwrap();
        let rw = whole.run(reqs.clone()).unwrap();
        let mut chunked = ServeEngine::new(Arc::clone(&model), mk(5)).unwrap();
        let rc = chunked.run(reqs.clone()).unwrap();
        assert_eq!(rw.tokens_by_id(), rc.tokens_by_id(), "chunking changed tokens");
        let seq = run_sequential(&model, &reqs).unwrap();
        assert_eq!(seq.tokens_by_id(), rc.tokens_by_id());
        assert_eq!(
            rc.stats.tick_s.len(),
            rc.stats.round_s.len(),
            "one tick sample per decode round"
        );
        assert!(rc.stats.try_tick_latency_us(99.0).is_some());
        // the whole workload's prompt tokens were still prefilled
        // exactly once each
        let total: usize = reqs.iter().map(|r| r.prompt.len()).sum();
        assert_eq!(rc.stats.prefill_tokens + rc.stats.prefill_tokens_saved, total);
    }

    #[test]
    fn chunked_prefill_interleaves_decode_with_a_late_long_prompt() {
        // a long prompt arriving while a short stream decodes must not
        // stall it: with chunking the prefilling session advances one
        // chunk per tick while the in-flight stream keeps producing a
        // token per tick
        let model = Arc::new(tiny_model(AttnSpec::H1d { nr: 2 }, 64));
        let mut eng = ServeEngine::new(
            Arc::clone(&model),
            ServeConfig {
                max_batch: 2,
                page_len: 4,
                prefill_chunk: 4,
                threads: 1,
                ..ServeConfig::default()
            },
        )
        .unwrap();
        let short = Request {
            id: 0,
            prompt: vec![1, 2, 3, 4],
            max_new: 10,
            temperature: 0.0,
            seed: 1,
        };
        let long = Request {
            id: 1,
            prompt: (0..24).map(|t| (t % 13) as u32).collect(),
            max_new: 3,
            temperature: 0.0,
            seed: 2,
        };
        eng.submit(short.clone()).unwrap();
        eng.tick(); // short admitted, decoding
        eng.submit(long.clone()).unwrap();
        let mut decoded_during_prefill = 0;
        for _ in 0..4 {
            let before: usize = {
                let mut t = 0;
                eng.for_each_active(|id, toks| {
                    if id == 0 {
                        t = toks.len();
                    }
                });
                t
            };
            eng.tick();
            let after: usize = {
                let mut t = 0;
                eng.for_each_active(|id, toks| {
                    if id == 0 {
                        t = toks.len();
                    }
                });
                t
            };
            decoded_during_prefill += after.saturating_sub(before);
        }
        assert!(
            decoded_during_prefill >= 3,
            "the short stream must keep decoding while the long prompt chunks \
             (got {decoded_during_prefill} tokens across 4 ticks)"
        );
        while eng.tick() {}
        let comps = eng.take_completions();
        assert_eq!(comps.len(), 2);
        // both streams bitwise match the sequential oracle
        let seq = run_sequential(&model, &[short, long]).unwrap();
        let mut by_id = comps.clone();
        by_id.sort_by_key(|c| c.id);
        for (s, c) in seq.completions.iter().zip(&by_id) {
            assert_eq!(s.id, c.id);
            assert_eq!(s.tokens, c.tokens);
        }
    }

    #[test]
    fn synthetic_workload_cycles_the_mix() {
        let reqs = synthetic_workload(5, &[3, 7], 4, 29, 0.5, 11);
        assert_eq!(reqs.len(), 5);
        let lens: Vec<usize> = reqs.iter().map(|r| r.prompt.len()).collect();
        assert_eq!(lens, vec![3, 7, 3, 7, 3]);
        assert!(reqs.iter().all(|r| r.max_new == 4 && r.temperature == 0.5));
        assert!(reqs.iter().all(|r| r.prompt.iter().all(|&t| t < 29)));
        // distinct per-request seeds
        let mut seeds: Vec<u64> = reqs.iter().map(|r| r.seed).collect();
        seeds.sort_unstable();
        seeds.dedup();
        assert_eq!(seeds.len(), 5);
    }

    #[test]
    fn latency_percentiles_survive_zero_completion_runs() {
        // a run where every request is rejected at admission produces
        // stats with no decode rounds: the percentile rank math
        // ((len - 1) on an empty sample set) must be guarded, not hit
        let stats = ServeStats::default();
        assert_eq!(stats.try_latency_us(50.0), None);
        assert_eq!(stats.latency_us(50.0), 0.0);
        assert_eq!(stats.latency_us(99.0), 0.0);
        assert_eq!(stats.per_token_us(), 0.0);
        // the engine-level shape of the same case: submits all fail,
        // run() drains nothing, and the report's percentiles are 0.0
        let model = Arc::new(tiny_model(AttnSpec::Full, 16));
        let mut eng = ServeEngine::new(Arc::clone(&model), ServeConfig::default()).unwrap();
        let bad = Request {
            id: 0,
            prompt: vec![99], // out of vocab: rejected
            max_new: 2,
            temperature: 0.0,
            seed: 1,
        };
        assert!(eng.submit(bad).is_err());
        let rep = eng.run(Vec::new()).unwrap();
        assert!(rep.completions.is_empty());
        assert_eq!(rep.stats.try_latency_us(95.0), None);
        assert_eq!(rep.stats.latency_us(95.0), 0.0);
        // a one-round run clamps out-of-range pct instead of panicking
        let rep = eng.run(synthetic_workload(1, &[4], 2, 29, 0.0, 1)).unwrap();
        assert!(rep.stats.try_latency_us(200.0).is_some());
    }

    #[test]
    fn cancel_releases_pages_and_recycles_the_slot() {
        let model = Arc::new(tiny_model(AttnSpec::H1d { nr: 4 }, 32));
        let mut eng = ServeEngine::new(
            Arc::clone(&model),
            ServeConfig {
                max_batch: 2,
                prefix_cache: 0, // cache off so live pages pin to zero
                threads: 1,
                ..ServeConfig::default()
            },
        )
        .unwrap();
        let reqs = synthetic_workload(2, &[6], 8, 29, 0.0, 5);
        for r in reqs.clone() {
            eng.submit(r).unwrap();
        }
        // admit both and run a couple of rounds mid-stream
        eng.tick();
        eng.tick();
        assert_eq!(eng.active_sessions(), 2);
        let mut streamed = 0;
        eng.for_each_active(|_, toks| streamed += toks.len());
        assert!(streamed >= 2, "both sessions should have tokens by now");
        // cancel one mid-stream: pages released, no completion emitted
        assert!(eng.cancel(reqs[0].id));
        assert!(!eng.cancel(reqs[0].id), "double-cancel finds nothing");
        assert_eq!(eng.active_sessions(), 1);
        while eng.tick() {}
        let comps = eng.take_completions();
        assert_eq!(comps.len(), 1, "cancelled request must not complete");
        assert_eq!(comps[0].id, reqs[1].id);
        assert_eq!(eng.stats().cancelled, 1);
        assert_eq!(eng.pool_stats().live, 0, "cancel must release every page");
        // the survivor's tokens are unaffected by the cancellation
        let seq = run_sequential(&model, &reqs[1..]).unwrap();
        assert_eq!(seq.completions[0].tokens, comps[0].tokens);
        // a cancelled-then-identical workload reuses the recycled slot:
        // the workspace snapshot is invariant across cancel/re-admit
        let snap = eng.capacity_snapshot();
        for r in reqs.clone() {
            eng.submit(r).unwrap();
        }
        eng.tick();
        eng.tick();
        assert!(eng.cancel(reqs[0].id));
        while eng.tick() {}
        eng.take_completions();
        assert_eq!(eng.capacity_snapshot(), snap, "cancel path must not allocate");
    }

    #[test]
    fn cancel_pending_request_never_runs() {
        let model = Arc::new(tiny_model(AttnSpec::Full, 16));
        let mut eng = ServeEngine::new(Arc::clone(&model), ServeConfig::default()).unwrap();
        let reqs = synthetic_workload(2, &[4], 3, 29, 0.0, 9);
        for r in reqs.clone() {
            eng.submit(r).unwrap();
        }
        assert!(eng.cancel(reqs[1].id), "pending request is cancellable");
        assert_eq!(eng.queued(), 1);
        while eng.tick() {}
        let comps = eng.take_completions();
        assert_eq!(comps.len(), 1);
        assert_eq!(comps[0].id, reqs[0].id);
    }

    #[test]
    fn shared_prefix_workload_repeats_one_prompt() {
        let reqs = shared_prefix_workload(4, 6, 3, 29, 0.0, 17);
        assert_eq!(reqs.len(), 4);
        assert!(reqs.iter().all(|r| r.prompt == reqs[0].prompt));
        assert_eq!(reqs[0].prompt.len(), 6);
        let mut seeds: Vec<u64> = reqs.iter().map(|r| r.seed).collect();
        seeds.sort_unstable();
        seeds.dedup();
        assert_eq!(seeds.len(), 4);
    }

    fn spec_cfg(draft: &str, k: usize, threads: usize) -> ServeConfig {
        ServeConfig {
            spec_draft: Some(SpecDraft::parse(draft).unwrap()),
            spec_k: k,
            threads,
            ..ServeConfig::default()
        }
    }

    #[test]
    fn speculative_serve_matches_sequential_across_the_zoo_and_threads() {
        // the tentpole pin: greedy AND sampled speculative serving is
        // bitwise the sequential oracle, for pyramid and full targets,
        // serial and pooled rounds alike — and the acceptance counters
        // sum exactly to the emitted tokens
        for attn in [AttnSpec::H1d { nr: 4 }, AttnSpec::Full] {
            let model = Arc::new(tiny_model(attn, 64));
            for temperature in [0.0f32, 0.7] {
                let reqs = synthetic_workload(6, &[7, 11], 12, 29, temperature, 23);
                let seq = run_sequential(&model, &reqs).unwrap();
                for threads in [1usize, 2] {
                    let mut eng = ServeEngine::new(
                        Arc::clone(&model),
                        spec_cfg("local:4,layers:1", 3, threads),
                    )
                    .unwrap();
                    let rep = eng.run(reqs.clone()).unwrap();
                    assert_eq!(
                        seq.tokens_by_id(),
                        rep.tokens_by_id(),
                        "speculative serving diverged (threads {threads}, temp {temperature})"
                    );
                    let mut by_id = rep.completions.clone();
                    by_id.sort_by_key(|c| c.id);
                    for (s, c) in seq.completions.iter().zip(&by_id) {
                        assert_eq!(s.last_logits, c.last_logits, "last_logits drifted");
                    }
                    let st = &rep.stats;
                    assert!(st.spec_rounds > 0 && st.draft_proposed > 0);
                    assert!(st.draft_accepted <= st.draft_proposed);
                    // every spec round emits accepted + 1 tokens, plus
                    // one prefill-sampled first token per request
                    assert_eq!(
                        st.draft_accepted + st.spec_rounds + reqs.len(),
                        st.generated,
                        "acceptance accounting must sum to emitted tokens"
                    );
                    assert_eq!(st.generated, 6 * 12);
                    assert!(st.spec_tokens_per_step() >= 1.0);
                }
            }
        }
    }

    #[test]
    fn spec_k_zero_degenerates_to_plain_one_token_rounds() {
        let model = Arc::new(tiny_model(AttnSpec::H1d { nr: 4 }, 48));
        let reqs = synthetic_workload(4, &[6, 9], 8, 29, 0.0, 31);
        let seq = run_sequential(&model, &reqs).unwrap();
        let mut eng =
            ServeEngine::new(Arc::clone(&model), spec_cfg("local:2,layers:1", 0, 1)).unwrap();
        let rep = eng.run(reqs.clone()).unwrap();
        assert_eq!(seq.tokens_by_id(), rep.tokens_by_id());
        let st = &rep.stats;
        assert_eq!(st.draft_proposed, 0, "k = 0 must never run the draft");
        assert_eq!(st.spec_rounds + reqs.len(), st.generated, "one token per round");
        assert_eq!(st.spec_tokens_per_step(), 1.0);
        assert_eq!(st.spec_acceptance_rate(), 0.0);
    }

    #[test]
    fn eviction_under_speculation_replays_identical_tokens() {
        // tight page budget: a session gets evicted mid-stream and
        // requeued; the speculative replay must regenerate the same
        // tokens, and the draft's pages must release with the target's
        let model = Arc::new(tiny_model(AttnSpec::H1d { nr: 4 }, 24));
        let mut eng = ServeEngine::new(
            Arc::clone(&model),
            ServeConfig {
                max_batch: 3,
                max_tokens: 20,
                page_len: 4,
                prefix_cache: 0, // live pages must pin to zero at the end
                ..spec_cfg("local:2,layers:1", 3, 1)
            },
        )
        .unwrap();
        let reqs = synthetic_workload(3, &[7], 9, 29, 0.0, 41);
        let rep = eng.run(reqs.clone()).unwrap();
        assert_eq!(rep.completions.len(), 3);
        assert!(rep.stats.evictions > 0, "the budget should force an eviction");
        assert!(rep.stats.peak_ctx_tokens <= 20, "budget exceeded");
        let seq = run_sequential(&model, &reqs).unwrap();
        assert_eq!(seq.tokens_by_id(), rep.tokens_by_id());
        assert_eq!(
            eng.pool_stats().live,
            0,
            "target and draft pages must all return to the pool"
        );
    }

    #[test]
    fn speculation_config_gates_surface_at_construction() {
        // pyramid target + compressed KV: rollback would replay from
        // dequantised rows, so the engine refuses the combination
        let model = Arc::new(tiny_model(AttnSpec::H1d { nr: 4 }, 24));
        let err = ServeEngine::new(
            Arc::clone(&model),
            ServeConfig {
                kv_dtype: PageDtype::F16,
                ..spec_cfg("local:2,layers:1", 2, 1)
            },
        )
        .err()
        .expect("h1d + f16 KV + speculation must be rejected");
        assert!(err.contains("F32"), "{err}");
        // full-attention targets may combine speculation with
        // compressed KV (no pyramid partials to replay) — and still
        // match the compressed sequential oracle
        let full = Arc::new(tiny_model(AttnSpec::Full, 24));
        let mut eng = ServeEngine::new(
            Arc::clone(&full),
            ServeConfig {
                kv_dtype: PageDtype::F16,
                ..spec_cfg("local:2,layers:1", 2, 1)
            },
        )
        .unwrap();
        let reqs = synthetic_workload(3, &[6], 5, 29, 0.0, 51);
        let rep = eng.run(reqs.clone()).unwrap();
        let seq = run_sequential_dtype(&full, &reqs, PageDtype::F16).unwrap();
        assert_eq!(seq.tokens_by_id(), rep.tokens_by_id());
        // a bad draft spec surfaces at construction, not at first tick
        let err = ServeEngine::new(
            full,
            ServeConfig {
                spec_draft: Some(SpecDraft {
                    local_radius: None,
                    n_layers: Some(9),
                }),
                spec_k: 2,
                ..ServeConfig::default()
            },
        )
        .err()
        .expect("an over-deep draft must be rejected");
        assert!(err.contains("layer count"), "{err}");
    }
}
