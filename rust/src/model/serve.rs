//! Continuous-batching decode scheduler — the multi-session serving
//! layer over the KV-cached decode API, where the paper's O(L)
//! attention actually earns its keep: a server for heavy traffic must
//! interleave prefill and decode across many concurrent generation
//! streams, not run one `DecodeSession` at a time.
//!
//! ## Paged KV memory
//!
//! Session KV state lives in fixed-size pool pages
//! ([`crate::tensor::PagePool`] / [`crate::tensor::PagedRows`]), not
//! per-session contiguous arenas. That changes the two things that used
//! to bound concurrency:
//!
//! * **Admission is page-accounted, not reservation-accounted.** In the
//!   default demand-grown mode a session is charged only for the
//!   context pages it has actually faulted (its layer-0/head-0 fine-K
//!   stream, ×`page_len`, is the designated "context tokens" measure),
//!   so `max_tokens` no longer pre-pays `max_new` tokens that may never
//!   be generated. Growth happens one page at a time per decode round;
//!   when the pool can't cover a round, the engine first drops
//!   prefix-cache entries (LRU), then evicts the **youngest** active
//!   session(s) and requeues their requests at the queue head — a
//!   deterministic out-of-pages policy that preserves FIFO order and,
//!   because every request re-runs from its own seeded RNG stream,
//!   never changes any request's tokens. `reserve = true` restores the
//!   PR-4 contiguous-reservation semantics (the baseline the serve
//!   bench compares against): the full `prompt + max_new` horizon is
//!   pre-faulted and charged at admission.
//! * **Identical prompts share pages.** A copy-on-write prefix cache
//!   keyed on prompt-token hashes keeps the per-`(layer, head)` page
//!   tables of recent prefills; a same-prompt admission clones them
//!   (refcount bumps — no page copies, no forward pass), making the
//!   shared-system-prompt workload O(1)-per-duplicate at prefill and
//!   counting the shared pages **once** against `max_tokens`. Shared
//!   pages are immutable: a session's first mutation of a boundary page
//!   (appending into a partially-filled tail, accumulating an h1d
//!   pyramid partial sum) copies it first, so only pages holding
//!   still-accumulating partials privatise — h1d pyramid pages stay
//!   shared exactly for fully-completed coarse blocks. Sharing is
//!   whole-prompt (a hit requires the full token sequence to match):
//!   prefill outputs are a pure function of the prompt, so the cloned
//!   state is bitwise what a fresh prefill would produce for **every**
//!   algorithm, including the non-causal and length-dependent ones.
//!
//! ## Scheduler state machine
//!
//! A request moves `pending → active → completed` through
//! [`ServeEngine::tick`], which runs one scheduling round:
//!
//!  1. **Admission** — while the head of the FIFO queue fits both
//!     budgets (`max_batch` concurrent sessions, `max_tokens` context
//!     pages), pop it, take a recycled slot from the session pool, and
//!     either clone the prefix-cache entry (hit) or run **one batched
//!     prefill forward** through the shared `ModelWorkspace` — the
//!     `run_trunk` observer bulk-loads every `(layer, head)`
//!     [`DecodeState`] — then sample the first token.
//!  2. **Growth staging** (demand-grown mode) — pre-fault every page
//!     this round's appends will touch (evicting as described above if
//!     the budget is exhausted), so worker-thread appends never take
//!     the pool lock.
//!  3. **Decode round** — every active session advances by one token
//!     through a ragged batched step: embeddings for all `n` sessions
//!     are assembled into `[n, D]` rows, each layer runs its LayerNorm
//!     / Q/K/V / output / FFN matmuls **once for the whole batch**, and
//!     attention goes through [`Attention::decode_step_batch`]. With
//!     `threads > 1` the active set is split into contiguous chunks
//!     that run on the crate thread pool.
//!  4. **Completion / eviction** — sessions that reached their
//!     `max_new` emit a [`Completion`]; their pages return to the pool
//!     and their slot (page tables, token and logits buffers included)
//!     recycles for the next admission.
//!
//! ## Ragged-batch layout
//!
//! Active sessions sit at different context lengths; nothing is padded.
//! Session `i` contributes row `i` of every `[n, ·]` activation matrix,
//! and its per-`(layer, head)` `DecodeState`s advance independently.
//! Because every per-row computation is independent and loop orders
//! match the single-session step path (page tables change the layout of
//! the caches, never the values or read order), batched logits are
//! **bitwise** what a lone `DecodeSession` produces — `tests/serve.rs`
//! pins batched-vs-sequential parity at 1e-5 and determinism under
//! arrival-order permutations.
//!
//! ## Budget knobs ([`ServeConfig`])
//!
//! * `max_batch` — concurrent-session cap (compute bound per round);
//! * `max_tokens` — context-token budget: page-granular tokens of
//!   fine-K context actually allocated across sessions and cache,
//!   shared pages counted once (a request whose rounded-up
//!   `prompt + max_new` could never fit is rejected at
//!   [`ServeEngine::submit`]);
//! * `page_len` — rows per KV page (power of two);
//! * `reserve` — contiguous-reservation admission (the paged-off
//!   baseline; disables the prefix cache);
//! * `prefix_cache` — retained prompt-cache entries (0 disables);
//! * `threads` — worker count for prefill head dispatch and chunked
//!   decode rounds (`<= 1` runs on the calling thread).
//!
//! Entry points: `htx serve-bench` (closed-loop synthetic workload,
//! paged vs reserved), `benches/serve.rs` (emits `BENCH_serve.json`,
//! the CI perf trajectory, including the shared-prefix paged points),
//! `examples/cpu_serve.rs`.

use std::collections::VecDeque;
use std::sync::Arc;
use std::time::Instant;

use super::{matmul_q, sample_logits, DecodeWorkspace, Model, ModelWorkspace, LN_EPS};
use crate::attention::DecodeState;
use crate::tensor::ops::{add_assign, add_bias_rows, gelu, layernorm_rows_into};
use crate::tensor::paged::DEFAULT_PAGE_LEN;
use crate::tensor::{Mat, PageDtype, PagePool, PoolStats};
use crate::util::bench::{derive_seed, synthetic_prompt};
use crate::util::Rng;

/// Scheduler budgets; see the module docs.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Maximum concurrently active sessions per round.
    pub max_batch: usize,
    /// Context-token budget: page-granular fine-K tokens allocated
    /// across active sessions and the prefix cache, shared pages
    /// counted once. In `reserve` mode the whole `prompt + max_new`
    /// horizon is charged at admission instead.
    pub max_tokens: usize,
    /// Rows per KV page (power of two). Smaller pages share prompt
    /// prefixes at finer granularity; larger pages amortise the page
    /// hop in the decode inner loop.
    pub page_len: usize,
    /// Pre-fault and charge the full `prompt + max_new` horizon at
    /// admission — the PR-4 contiguous-reservation baseline semantics
    /// (no demand growth, no eviction, prefix cache disabled).
    pub reserve: bool,
    /// Retained prefix-cache entries (0 disables the cache; ignored in
    /// `reserve` mode).
    pub prefix_cache: usize,
    /// Worker threads for prefill and chunked decode rounds
    /// (`<= 1` means the calling thread).
    pub threads: usize,
    /// Storage dtype for every session's fine K/V pages. `F16`/`I8`
    /// pages hold the same `page_len` rows in fewer f32 slots, so each
    /// budgeted page charges proportionally fewer context tokens
    /// against `max_tokens` — compressed caches admit more concurrent
    /// sessions under the same budget, at bounded decode drift.
    pub kv_dtype: PageDtype,
}

impl Default for ServeConfig {
    fn default() -> Self {
        Self {
            max_batch: 8,
            max_tokens: usize::MAX,
            page_len: DEFAULT_PAGE_LEN,
            reserve: false,
            prefix_cache: 8,
            threads: 1,
            kv_dtype: PageDtype::F32,
        }
    }
}

/// One generation request: a prompt, a token budget and per-request
/// sampling parameters (greedy at `temperature <= 0`, otherwise a
/// seeded softmax draw — each request owns its RNG stream, so results
/// are independent of batch composition, and an evicted-and-requeued
/// request regenerates exactly the same tokens).
#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<u32>,
    /// Tokens to generate (>= 1); the first is sampled from the
    /// prefill logits, exactly like the sequential `htx generate` loop.
    pub max_new: usize,
    pub temperature: f32,
    pub seed: u64,
}

/// A finished request: the generated tokens plus the `[vocab]` logits
/// of the final generated position (the parity pin for tests).
#[derive(Clone, Debug)]
pub struct Completion {
    pub id: u64,
    pub prompt_len: usize,
    pub tokens: Vec<u32>,
    pub last_logits: Vec<f32>,
    /// Round index at which the request was admitted / finished. Once
    /// admitted a session produces one token per round, so these mark
    /// *when* the request held a slot; queueing delay before admission
    /// is visible engine-wide as rounds where `queued() > 0`. An
    /// evicted request reports its final (successful) admission.
    pub admitted_round: usize,
    pub finished_round: usize,
}

/// Aggregate serving metrics for one run.
#[derive(Clone, Debug, Default)]
pub struct ServeStats {
    /// Decode rounds executed.
    pub rounds: usize,
    /// Tokens generated (prefill-sampled first tokens included).
    pub generated: usize,
    /// Prompt tokens prefilled (prefix-cache hits prefill nothing).
    pub prefill_tokens: usize,
    /// Total wall time across ticks (admission + rounds), seconds.
    pub wall_s: f64,
    /// Wall time of each decode round. Admission/prefill time is
    /// excluded (it shows up in `wall_s` and therefore throughput), so
    /// the p50/p95 derived from these samples measures the same thing
    /// as the sequential baseline's per-`step` samples.
    pub round_s: Vec<f64>,
    /// Tokens produced by each round (= active sessions that round).
    pub round_tokens: Vec<usize>,
    /// Peak concurrently active sessions.
    pub peak_active: usize,
    /// Prefix-cache lookups / hits (identical-prompt admissions that
    /// skipped the prefill forward entirely).
    pub prefix_lookups: usize,
    pub prefix_hits: usize,
    /// Sessions evicted and requeued by the out-of-pages policy.
    pub evictions: usize,
    /// Requests cancelled via [`ServeEngine::cancel`] (client
    /// disconnects); their pages were released and no [`Completion`]
    /// was emitted.
    pub cancelled: usize,
    /// Peak page-granular context tokens allocated (shared pages
    /// counted once) — what `max_tokens` bounds.
    pub peak_ctx_tokens: usize,
    /// Peak unique KV pages alive in the pool, all streams (fine K/V,
    /// Q history, pyramid levels).
    pub peak_pages: usize,
}

impl ServeStats {
    /// Aggregate throughput: generated tokens per wall second.
    pub fn tokens_per_sec(&self) -> f64 {
        if self.wall_s > 0.0 {
            self.generated as f64 / self.wall_s
        } else {
            0.0
        }
    }

    /// Aggregate per-token cost in µs (`wall / generated`) — the
    /// regression-gate metric of `BENCH_serve.json`.
    pub fn per_token_us(&self) -> f64 {
        if self.generated > 0 {
            self.wall_s * 1e6 / self.generated as f64
        } else {
            0.0
        }
    }

    /// Per-token latency percentile in µs: every token generated in a
    /// round observes that round's wall time (`pct` in 0..=100).
    /// `None` when no decode round ran — a zero-completion run (every
    /// request rejected at admission, or a stats read before the first
    /// round) has no latency distribution to index into; the old
    /// `(samples.len() - 1)` rank math must never see that case.
    pub fn try_latency_us(&self, pct: f64) -> Option<f64> {
        let mut samples: Vec<f64> = Vec::new();
        for (s, n) in self.round_s.iter().zip(&self.round_tokens) {
            samples.extend(std::iter::repeat(*s * 1e6).take(*n));
        }
        if samples.is_empty() {
            return None;
        }
        samples.sort_by(|a, b| a.partial_cmp(b).expect("finite latencies"));
        let idx = ((pct.clamp(0.0, 100.0) / 100.0) * (samples.len() - 1) as f64).round() as usize;
        Some(samples[idx.min(samples.len() - 1)])
    }

    /// [`ServeStats::try_latency_us`] with the empty case reported as
    /// `0.0` — the `BENCH_serve.json` convention.
    pub fn latency_us(&self, pct: f64) -> f64 {
        self.try_latency_us(pct).unwrap_or(0.0)
    }

    /// Mean active sessions per decode round (batch fill).
    pub fn mean_occupancy(&self) -> f64 {
        if self.round_tokens.is_empty() {
            0.0
        } else {
            self.round_tokens.iter().sum::<usize>() as f64 / self.round_tokens.len() as f64
        }
    }

    /// Fraction of admissions served from the prefix cache.
    pub fn prefix_hit_rate(&self) -> f64 {
        if self.prefix_lookups == 0 {
            0.0
        } else {
            self.prefix_hits as f64 / self.prefix_lookups as f64
        }
    }
}

/// Completions plus run-level stats — returned by both
/// [`ServeEngine::run`] and the [`run_sequential`] baseline.
#[derive(Clone, Debug)]
pub struct ServeReport {
    pub completions: Vec<Completion>,
    pub stats: ServeStats,
}

impl ServeReport {
    /// Generated tokens keyed and sorted by request id — the
    /// scheduling-invariant view two runs of one workload must agree
    /// on. The parity guard shared by `htx serve-bench`,
    /// `benches/serve.rs` and the test suite: batching, chunking,
    /// paging, prefix sharing and eviction may change *when* a request
    /// runs, never *what* it generates.
    pub fn tokens_by_id(&self) -> Vec<(u64, &[u32])> {
        let mut out: Vec<(u64, &[u32])> = self
            .completions
            .iter()
            .map(|c| (c.id, c.tokens.as_slice()))
            .collect();
        out.sort_by_key(|(id, _)| *id);
        out
    }
}

/// FNV-1a over the prompt token ids — the prefix-cache key (full token
/// equality is re-checked on every hit, so collisions cost a compare,
/// never a wrong share).
fn hash_tokens(tokens: &[u32]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &t in tokens {
        h ^= t as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

/// One retained prompt prefill: the per-`(layer, head)` states sharing
/// the prompt's pages (never stepped — scratch stays empty) plus the
/// final-position residual row for first-token logits on a hit.
struct CacheEntry {
    prompt: Vec<u32>,
    hash: u64,
    states: Vec<DecodeState>,
    last_x: Vec<f32>,
    /// Pyramid depth the states were prefilled at; a hit requires the
    /// admitting session to need no deeper pyramid (shallower levels
    /// are a prefix of deeper ones, so sharing down is exact).
    n_coarse: usize,
    /// Largest `prompt + max_new` horizon this entry is known to serve.
    /// Pyramid depth is monotone in the horizon, so a request whose own
    /// horizon fits inside it is **guaranteed** to satisfy the
    /// `n_coarse` check above — the admission accounting predicts a
    /// free hit only under this guarantee, keeping the context budget
    /// sound. A deeper request is conservatively charged a full
    /// prefill; if it still hits (its depth fits anyway — always for
    /// the non-hierarchical algorithms), the hit **ratchets** this
    /// horizon so later duplicates are predicted correctly, and if it
    /// misses, its re-prefill replaces the entry at the deeper horizon.
    horizon: usize,
}

/// One pooled session: the per-`(layer, head)` KV states plus request
/// bookkeeping. Slots recycle through the engine's free pool — page
/// tables, token and logits buffers are grow-only, so same-shape
/// re-admissions allocate nothing outside the page pool.
struct SessionSlot {
    id: u64,
    prompt_len: usize,
    max_new: usize,
    /// `prompt + max_new`, the session's context horizon (pages are
    /// faulted up to here on demand; fully pre-faulted in reserve
    /// mode).
    budget: usize,
    temperature: f32,
    rng: Rng,
    /// Tokens consumed so far = position the next fed token decodes at.
    pos: usize,
    /// Last sampled token, fed in the next round.
    next_token: u32,
    /// Generated tokens (capacity reserved to `max_new` at admission).
    tokens: Vec<u32>,
    /// `[vocab]` logits of the final generated position, filled at
    /// completion (capacity reserved at admission).
    logits: Vec<f32>,
    /// `layer * n_heads + head` order, like `DecodeWorkspace`.
    states: Vec<DecodeState>,
    /// The original request, kept so an out-of-pages eviction can
    /// requeue it verbatim.
    request: Option<Request>,
    admitted_round: usize,
    done: bool,
}

impl SessionSlot {
    fn fresh() -> Self {
        Self {
            id: 0,
            prompt_len: 0,
            max_new: 0,
            budget: 0,
            temperature: 0.0,
            rng: Rng::new(0),
            pos: 0,
            next_token: 0,
            tokens: Vec::new(),
            logits: Vec::new(),
            states: Vec::new(),
            request: None,
            admitted_round: 0,
            done: false,
        }
    }
}

/// Per-worker activation buffers for one chunk of a decode round —
/// the `[n, ·]` counterpart of the `[1, ·]` buffers in
/// `DecodeWorkspace`. Grow-only, recycled round to round.
#[derive(Default)]
struct StepBuf {
    x: Mat,
    hn: Mat,
    q: Mat,
    k: Mat,
    v: Mat,
    merged: Mat,
    proj: Mat,
    ff: Mat,
    logits: Mat,
}

impl StepBuf {
    fn snapshot(&self) -> Vec<(usize, usize)> {
        [
            &self.x,
            &self.hn,
            &self.q,
            &self.k,
            &self.v,
            &self.merged,
            &self.proj,
            &self.ff,
            &self.logits,
        ]
        .iter()
        .map(|m| (m.data.as_ptr() as usize, m.data.capacity()))
        .collect()
    }
}

/// One ragged decode round over `slots`: embed every session's pending
/// token at its own position, run each layer's batched matmuls once for
/// the chunk, advance all per-head caches through
/// `Attention::decode_step_batch`, then sample each session's next
/// token from the batched logits. Row `i` is bitwise the
/// single-session step path (loop orders match; every per-row op reads
/// only row `i`; the paged caches were staged by the scheduler thread,
/// so appends here are lock-free).
///
/// KEEP IN SYNC with `DecodeSession::step` (decode.rs): this is that
/// layer schedule at `[n, D]` instead of `[1, D]`, differing only in
/// `decode_step_batch` vs per-head `decode_step`. Any change to the
/// block structure must land in both; `tests/serve.rs` pins the parity
/// at 1e-5 so drift fails loudly.
fn step_slots(model: &Model, slots: &mut [SessionSlot], buf: &mut StepBuf) {
    if slots.is_empty() {
        return;
    }
    let cfg = &model.cfg;
    let p = &model.params;
    let n = slots.len();
    let (d, n_heads) = (cfg.d_model, cfg.n_heads);
    let n_states = cfg.n_layers * n_heads;

    // token + positional embedding for every session's current position
    buf.x.reset_for_overwrite(n, d);
    for (i, slot) in slots.iter().enumerate() {
        debug_assert!(
            slot.states[..n_states].iter().all(|st| st.remaining() > 0),
            "session {} stepped beyond its reserved context",
            slot.id
        );
        let row = buf.x.row_mut(i);
        for ((o, e), ps) in row
            .iter_mut()
            .zip(p.embed.row(slot.next_token as usize))
            .zip(p.pos.row(slot.pos))
        {
            *o = e + ps;
        }
    }

    for (layer, lp) in p.layers.iter().enumerate() {
        let lq = model.layer_quant(layer);
        // pre-LN attention block at [n, D]; one weight read per matrix
        layernorm_rows_into(&buf.x, &lp.ln1_scale, &lp.ln1_bias, LN_EPS, &mut buf.hn);
        matmul_q(&buf.hn, &lp.wq, lq.map(|q| &q.wq), &mut buf.q);
        matmul_q(&buf.hn, &lp.wk, lq.map(|q| &q.wk), &mut buf.k);
        matmul_q(&buf.hn, &lp.wv, lq.map(|q| &q.wv), &mut buf.v);
        buf.merged.reset_for_overwrite(n, d);
        let mut layer_states: Vec<&mut [DecodeState]> = slots
            .iter_mut()
            .map(|s| &mut s.states[layer * n_heads..(layer + 1) * n_heads])
            .collect();
        model.algo.decode_step_batch(
            &mut layer_states,
            &buf.q,
            &buf.k,
            &buf.v,
            cfg.causal,
            &mut buf.merged,
        );
        matmul_q(&buf.merged, &lp.wo, lq.map(|q| &q.wo), &mut buf.proj);
        add_assign(&mut buf.x, &buf.proj);

        // pre-LN feed-forward block
        layernorm_rows_into(&buf.x, &lp.ln2_scale, &lp.ln2_bias, LN_EPS, &mut buf.hn);
        matmul_q(&buf.hn, &lp.ff_w1, lq.map(|q| &q.ff_w1), &mut buf.ff);
        add_bias_rows(&mut buf.ff, &lp.ff_b1);
        gelu(&mut buf.ff);
        matmul_q(&buf.ff, &lp.ff_w2, lq.map(|q| &q.ff_w2), &mut buf.proj);
        add_bias_rows(&mut buf.proj, &lp.ff_b2);
        add_assign(&mut buf.x, &buf.proj);
    }

    model.logits_into(&buf.x, &mut buf.hn, &mut buf.logits);
    for (i, slot) in slots.iter_mut().enumerate() {
        slot.pos += 1;
        let row = buf.logits.row(i);
        let t = sample_logits(row, slot.temperature, &mut slot.rng) as u32;
        slot.tokens.push(t);
        if slot.tokens.len() >= slot.max_new {
            slot.done = true;
            slot.logits.clear();
            slot.logits.extend_from_slice(row);
        } else {
            slot.next_token = t;
        }
    }
}

/// The continuous-batching scheduler; see the module docs. Owns the
/// model through an `Arc` so chunked rounds can travel through the
/// thread pool's `'static` jobs.
pub struct ServeEngine {
    model: Arc<Model>,
    cfg: ServeConfig,
    /// Context tokens one budgeted fine-K page charges under
    /// `cfg.kv_dtype` (`page_len` for f32; fewer for f16/int8) — the
    /// conversion factor between page counts and the `max_tokens`
    /// budget, precomputed at construction.
    kv_page_cost: usize,
    /// Shared KV page pool for every session's caches and the prefix
    /// cache; its accounting drives admission and growth (module docs).
    pool: PagePool,
    /// Prefix cache, LRU at the front / MRU at the back.
    cache: Vec<CacheEntry>,
    /// Shared batched-forward arena for admission prefills; its
    /// attention pool doubles as the decode-round worker pool (one set
    /// of OS threads per engine — prefill and rounds never overlap).
    prefill: ModelWorkspace,
    /// `[1, ·]` admission head-logits path (first-token sampling).
    adm_x: Mat,
    adm_hn: Mat,
    adm_logits: Mat,
    pending: VecDeque<Request>,
    active: Vec<SessionSlot>,
    /// Session pool: retired slots waiting to be re-admitted.
    free: Vec<SessionSlot>,
    /// Reusable chunk containers for pooled rounds (one per worker).
    chunk_store: Vec<Vec<SessionSlot>>,
    /// Per-worker step buffers.
    bufs: Vec<StepBuf>,
    completions: Vec<Completion>,
    stats: ServeStats,
}

impl ServeEngine {
    pub fn new(model: Arc<Model>, cfg: ServeConfig) -> Result<ServeEngine, String> {
        if cfg.max_batch == 0 {
            return Err("max_batch must be >= 1".to_string());
        }
        if cfg.max_tokens == 0 {
            return Err("max_tokens budget must be >= 1".to_string());
        }
        if cfg.page_len == 0 || !cfg.page_len.is_power_of_two() {
            return Err(format!(
                "page_len must be a power of two >= 1 (got {})",
                cfg.page_len
            ));
        }
        let threads = cfg.threads.max(1);
        let kv_page_cost = cfg.kv_dtype.page_ctx_cost(cfg.page_len, model.cfg.d_head());
        Ok(ServeEngine {
            kv_page_cost,
            pool: PagePool::new(cfg.page_len),
            cache: Vec::new(),
            prefill: ModelWorkspace::new(threads),
            adm_x: Mat::default(),
            adm_hn: Mat::default(),
            adm_logits: Mat::default(),
            pending: VecDeque::new(),
            active: Vec::with_capacity(cfg.max_batch),
            free: Vec::with_capacity(cfg.max_batch),
            chunk_store: (0..threads).map(|_| Vec::with_capacity(cfg.max_batch)).collect(),
            bufs: (0..threads).map(|_| StepBuf::default()).collect(),
            completions: Vec::new(),
            stats: ServeStats::default(),
            model,
            cfg,
        })
    }

    /// Validate and enqueue a request (FIFO). Rejects requests that
    /// could never run: empty prompt, `max_new == 0`, token ids outside
    /// the vocabulary, an overflowing or over-`max_len` context
    /// horizon, or a page-rounded horizon exceeding the engine's
    /// `max_tokens` budget even when the session runs alone.
    pub fn submit(&mut self, req: Request) -> Result<(), String> {
        self.validate(&req)?;
        self.pending.push_back(req);
        Ok(())
    }

    /// The [`ServeEngine::submit`] admission checks, side-effect free.
    fn validate(&self, req: &Request) -> Result<(), String> {
        let mcfg = &self.model.cfg;
        if req.prompt.is_empty() {
            return Err(format!("request {}: empty prompt", req.id));
        }
        if req.max_new == 0 {
            return Err(format!("request {}: max_new must be >= 1", req.id));
        }
        let budget = req.prompt.len().checked_add(req.max_new).ok_or_else(|| {
            format!(
                "request {}: prompt length {} + max_new {} overflows the context horizon",
                req.id,
                req.prompt.len(),
                req.max_new
            )
        })?;
        if budget > mcfg.max_len {
            return Err(format!(
                "request {}: prompt {} + max_new {} exceeds model max_len {}",
                req.id,
                req.prompt.len(),
                req.max_new,
                mcfg.max_len
            ));
        }
        // page-granular: the horizon this session could grow to, alone
        // (each page charges kv_page_cost tokens — fewer when the KV
        // pages are compressed)
        let granular = budget
            .div_ceil(self.cfg.page_len)
            .saturating_mul(self.kv_page_cost);
        if granular > self.cfg.max_tokens {
            return Err(format!(
                "request {}: page-rounded context reservation {granular} exceeds the \
                 max_tokens budget {}",
                req.id, self.cfg.max_tokens
            ));
        }
        if let Some(&bad) = req.prompt.iter().find(|&&t| t as usize >= mcfg.vocab_size) {
            return Err(format!(
                "request {}: token id {bad} >= vocab {}",
                req.id, mcfg.vocab_size
            ));
        }
        Ok(())
    }

    /// Queued requests not yet admitted.
    pub fn queued(&self) -> usize {
        self.pending.len()
    }

    /// Currently active sessions.
    pub fn active_sessions(&self) -> usize {
        self.active.len()
    }

    /// Run-so-far metrics (reset by [`ServeEngine::run`]).
    pub fn stats(&self) -> &ServeStats {
        &self.stats
    }

    /// Page-pool accounting right now (live/free/budgeted pages).
    pub fn pool_stats(&self) -> PoolStats {
        self.pool.stats()
    }

    /// Prefix-cache entries currently retained.
    pub fn prefix_cache_len(&self) -> usize {
        self.cache.len()
    }

    /// Completions accumulated so far (drains the internal buffer).
    pub fn take_completions(&mut self) -> Vec<Completion> {
        std::mem::take(&mut self.completions)
    }

    /// Visit every active session's generated-so-far tokens. The net
    /// front end calls this after each [`ServeEngine::tick`] to stream
    /// newly generated tokens; callers keep their own per-request
    /// watermark, so an out-of-pages eviction (which clears and later
    /// regenerates bitwise-identical tokens) simply pauses the stream
    /// instead of double-sending.
    pub fn for_each_active(&self, mut f: impl FnMut(u64, &[u32])) {
        for slot in &self.active {
            f(slot.id, &slot.tokens);
        }
    }

    /// Cancel a request by id — a client disconnect mid-stream. A
    /// pending request is dropped from the queue; an active session is
    /// torn down in place: its pages return to the pool, its generated
    /// tokens come off the `generated` count (they were never
    /// delivered) and **no** [`Completion`] is emitted. The slot
    /// recycles through the session pool exactly like a retirement, so
    /// cancellation leaks nothing — `capacity_snapshot` is invariant
    /// across a cancel + same-shape re-admission. Returns whether the
    /// id was found (pending or active).
    pub fn cancel(&mut self, id: u64) -> bool {
        if let Some(i) = self.pending.iter().position(|r| r.id == id) {
            self.pending.remove(i);
            self.stats.cancelled += 1;
            return true;
        }
        if let Some(i) = self.active.iter().position(|s| s.id == id) {
            let mut slot = self.active.remove(i);
            slot.request = None;
            self.stats.generated -= slot.tokens.len();
            slot.tokens.clear();
            slot.logits.clear();
            for st in &mut slot.states {
                st.release_pages();
            }
            self.free.push(slot);
            self.stats.cancelled += 1;
            return true;
        }
        false
    }

    fn cache_limit(&self) -> usize {
        if self.cfg.reserve {
            0
        } else {
            self.cfg.prefix_cache
        }
    }

    /// Whether `extra_tokens` more context tokens fit `max_tokens`
    /// (tokens are dtype-weighted: the pool tracks each budgeted page
    /// at its `ctx_cost`, so compressed pages count for less).
    fn fits_ctx(&self, extra_tokens: usize) -> bool {
        if self.cfg.max_tokens == usize::MAX {
            return true;
        }
        self.pool.stats().ctx_tokens().saturating_add(extra_tokens) <= self.cfg.max_tokens
    }

    /// Context tokens admitting `req` would charge right now. A free
    /// cache hit is predicted only when [`ServeEngine::cache_predicts_hit`]
    /// *guarantees* the hit path in `admit` will take it; otherwise the
    /// full prompt prefill is charged conservatively, so the context
    /// budget can never be exceeded by a predicted-hit-turned-miss.
    fn admission_ctx_tokens(&self, req: &Request) -> usize {
        let pages = if self.cfg.reserve {
            (req.prompt.len() + req.max_new).div_ceil(self.cfg.page_len)
        } else if self.cache_limit() > 0 && self.cache_predicts_hit(req) {
            0
        } else {
            req.prompt.len().div_ceil(self.cfg.page_len)
        };
        pages.saturating_mul(self.kv_page_cost)
    }

    /// Sound hit predictor: the tokens match and the request's horizon
    /// fits inside the entry's. Pyramid depth (`n_coarse`) is monotone
    /// in the horizon for every algorithm, so this implies the
    /// `n_coarse >= min_coarse` check `cache_position` performs —
    /// predicted hits always hit.
    fn cache_predicts_hit(&self, req: &Request) -> bool {
        let h = hash_tokens(&req.prompt);
        let horizon = req.prompt.len() + req.max_new;
        self.cache
            .iter()
            .any(|e| e.hash == h && horizon <= e.horizon && e.prompt == req.prompt)
    }

    fn cache_position(&self, prompt: &[u32], min_coarse: usize) -> Option<usize> {
        let h = hash_tokens(prompt);
        self.cache
            .iter()
            .position(|e| e.hash == h && e.n_coarse >= min_coarse && e.prompt == prompt)
    }

    /// Drop the least-recently-used cache entry to free page budget.
    /// Returns false when the cache is already empty. Freed pages are
    /// only those no live session still shares.
    fn drop_lru_cache_entry(&mut self) -> bool {
        if self.cache.is_empty() {
            return false;
        }
        self.cache.remove(0);
        true
    }

    fn cache_insert(&mut self, prompt: &[u32], states: &[DecodeState], last_x: &[f32]) {
        let hash = hash_tokens(prompt);
        if let Some(i) = self
            .cache
            .iter()
            .position(|e| e.hash == hash && e.prompt == prompt)
        {
            // replace (a re-prefill at a deeper horizon supersedes it)
            self.cache.remove(i);
        }
        let entry = CacheEntry {
            prompt: prompt.to_vec(),
            hash,
            states: states.iter().map(|s| s.snapshot_shared()).collect(),
            last_x: last_x.to_vec(),
            n_coarse: states.first().map(|s| s.n_coarse).unwrap_or(0),
            horizon: states.first().map(|s| s.max_len).unwrap_or(0),
        };
        self.cache.push(entry);
        while self.cache.len() > self.cache_limit() {
            self.cache.remove(0);
        }
    }

    /// `(pointer, capacity)` of every workspace buffer the engine owns
    /// — session slots (active and pooled) with their page tables and
    /// pages, prefix-cache entries, step buffers, the prefill arena,
    /// the admission head path and the page pool's free list plus its
    /// total-pages marker. Sorted, so the snapshot is invariant to
    /// slots migrating between the active set and the pool and to
    /// pages migrating between sessions, the cache and the free list;
    /// equal snapshots across ticks prove the steady state allocates
    /// nothing in any workspace **and grows the page pool by zero
    /// pages** (request outputs — completion token/logit copies — are
    /// not workspace and are excluded).
    pub fn capacity_snapshot(&self) -> Vec<(usize, usize)> {
        let mut out: Vec<(usize, usize)> = Vec::new();
        for slot in self.active.iter().chain(self.free.iter()) {
            out.push((slot.states.as_ptr() as usize, slot.states.capacity()));
            for st in &slot.states {
                out.extend(st.buffer_snapshot());
            }
            out.push((slot.tokens.as_ptr() as usize, slot.tokens.capacity()));
            out.push((slot.logits.as_ptr() as usize, slot.logits.capacity()));
        }
        for e in &self.cache {
            out.push((e.prompt.as_ptr() as usize, e.prompt.capacity()));
            out.push((e.last_x.as_ptr() as usize, e.last_x.capacity()));
            out.push((e.states.as_ptr() as usize, e.states.capacity()));
            for st in &e.states {
                out.extend(st.buffer_snapshot());
            }
        }
        for b in &self.bufs {
            out.extend(b.snapshot());
        }
        for c in &self.chunk_store {
            out.push((c.as_ptr() as usize, c.capacity()));
        }
        out.extend(self.pool.capacity_snapshot());
        out.extend(self.prefill.capacity_snapshot());
        for m in [&self.adm_x, &self.adm_hn, &self.adm_logits] {
            out.push((m.data.as_ptr() as usize, m.data.capacity()));
        }
        out.sort_unstable();
        out
    }

    /// Admit one request into a (recycled) session slot: wire its
    /// per-`(layer, head)` states to the shared page pool, then either
    /// clone the prefix-cache entry for an identical prompt (no
    /// forward pass, no page copies) or run the batched prefill
    /// forward, and sample the first token from the prompt's final
    /// logits. A request whose `max_new` is 1 completes here and never
    /// enters a decode round.
    ///
    /// KEEP IN SYNC with `Model::prefill_with` (decode.rs): same
    /// state-begin + `run_trunk` observer sequence, pooled instead of
    /// per-`DecodeWorkspace` (the one semantic difference: states are
    /// reserved to the request horizon, not `max_len` — h1d's step
    /// output is invariant to the extra pyramid depth).
    fn admit(&mut self, req: Request) {
        let model = Arc::clone(&self.model);
        let mcfg = &model.cfg;
        let n_heads = mcfg.n_heads;
        let d_model = mcfg.d_model;
        let n_states = mcfg.n_layers * n_heads;
        let mut slot = self.free.pop().unwrap_or_else(SessionSlot::fresh);
        slot.id = req.id;
        slot.prompt_len = req.prompt.len();
        slot.max_new = req.max_new;
        slot.budget = req.prompt.len() + req.max_new;
        slot.temperature = req.temperature;
        slot.rng = Rng::new(req.seed);
        slot.pos = req.prompt.len();
        slot.tokens.clear();
        slot.tokens.reserve(req.max_new);
        slot.logits.clear();
        slot.logits.reserve(mcfg.vocab_size);
        slot.admitted_round = self.stats.rounds;
        slot.done = false;
        while slot.states.len() < n_states {
            slot.states.push(DecodeState::default());
        }
        for st in &mut slot.states[..n_states] {
            st.attach_pool(&self.pool, self.cfg.reserve);
            st.set_kv_dtype(self.cfg.kv_dtype);
        }
        // layer-0/head-0 fine K is the budgeted "context tokens" stream
        slot.states[0].mark_ctx_stream();
        for st in &mut slot.states[..n_states] {
            model.algo.decode_begin(st, slot.budget, mcfg.d_head());
        }

        // prefix cache: an identical prompt clones the cached page
        // tables (refcount bumps) instead of re-running the prefill
        let mut hit = false;
        if self.cache_limit() > 0 {
            self.stats.prefix_lookups += 1;
            let min_coarse = slot.states[0].n_coarse;
            if let Some(i) = self.cache_position(&req.prompt, min_coarse) {
                let mut entry = self.cache.remove(i);
                for (st, cst) in slot.states[..n_states].iter_mut().zip(&entry.states) {
                    cst.clone_shared_into(st);
                }
                self.adm_x.reset_for_overwrite(1, d_model);
                self.adm_x.row_mut(0).copy_from_slice(&entry.last_x);
                // this hit proves the entry's depth serves this horizon:
                // ratchet it so later duplicates are *predicted* as hits
                // by admission_ctx_pages instead of being conservatively
                // charged a prefill they will never run
                entry.horizon = entry.horizon.max(slot.budget);
                self.cache.push(entry); // back to the MRU position
                self.stats.prefix_hits += 1;
                hit = true;
            }
        }
        if !hit {
            // one batched forward over the prompt; the observer
            // bulk-loads every (layer, head) cache — the decode.rs
            // prefill, pooled
            let states = &mut slot.states;
            model.run_trunk(&mut self.prefill, &req.prompt, 1, |layer, qkv| {
                for h in 0..n_heads {
                    model.algo.decode_load_prefix(
                        &mut states[layer * n_heads + h],
                        qkv.q.head(h),
                        qkv.k.head(h),
                        qkv.v.head(h),
                    );
                }
            });
            self.stats.prefill_tokens += req.prompt.len();
            self.adm_x.reset_for_overwrite(1, d_model);
            self.adm_x
                .row_mut(0)
                .copy_from_slice(self.prefill.x.row(req.prompt.len() - 1));
            if self.cache_limit() > 0 {
                let last_x = self.adm_x.row(0).to_vec();
                self.cache_insert(&req.prompt, &slot.states[..n_states], &last_x);
            }
        }

        // first-token logits from the last prompt position
        model.logits_into(&self.adm_x, &mut self.adm_hn, &mut self.adm_logits);
        let row = self.adm_logits.row(0);
        let t = sample_logits(row, slot.temperature, &mut slot.rng) as u32;
        slot.tokens.push(t);
        self.stats.generated += 1;
        slot.request = Some(req);
        if slot.tokens.len() >= slot.max_new {
            slot.done = true;
            slot.logits.clear();
            slot.logits.extend_from_slice(row);
            // the session held a slot during its prefill even though it
            // never enters a decode round — count it as active
            self.stats.peak_active = self.stats.peak_active.max(self.active.len() + 1);
            self.retire(slot);
        } else {
            slot.next_token = t;
            self.active.push(slot);
            self.stats.peak_active = self.stats.peak_active.max(self.active.len());
        }
    }

    /// Emit a [`Completion`], return the slot's pages to the pool and
    /// recycle the slot. Page tables and token/logit buffers keep
    /// their capacity, so a same-shape re-admission allocates nothing
    /// outside the (warm) page pool.
    fn retire(&mut self, mut slot: SessionSlot) {
        self.completions.push(Completion {
            id: slot.id,
            prompt_len: slot.prompt_len,
            tokens: slot.tokens.clone(),
            last_logits: slot.logits.clone(),
            admitted_round: slot.admitted_round,
            finished_round: self.stats.rounds,
        });
        slot.tokens.clear();
        slot.logits.clear();
        slot.request = None;
        for st in &mut slot.states {
            st.release_pages();
        }
        self.free.push(slot);
    }

    /// One scheduling round: admit what fits, stage this round's page
    /// growth (evicting under pressure), run one ragged decode round
    /// over the active set, retire finished sessions. Returns whether
    /// work remains (pending or active requests).
    pub fn tick(&mut self) -> bool {
        let t0 = Instant::now();
        let n_states = self.model.cfg.n_layers * self.model.cfg.n_heads;

        // admission: head-of-line FIFO within the batch and context
        // budgets; under page pressure the LRU cache entries go first
        loop {
            if self.active.len() >= self.cfg.max_batch {
                break;
            }
            let needed = match self.pending.front() {
                None => break,
                Some(r) => self.admission_ctx_tokens(r),
            };
            if !self.fits_ctx(needed) {
                if self.drop_lru_cache_entry() {
                    continue;
                }
                break;
            }
            let req = self.pending.pop_front().expect("checked front");
            self.admit(req);
        }

        // demand-grown rounds: pre-fault every page this round's
        // appends will touch, so worker-thread appends are lock-free.
        // Out of pages → drop cache entries (LRU), then evict the
        // youngest session(s) and requeue at the queue head: FIFO order
        // is preserved (older sessions never lose their slot to younger
        // ones) and the requeued request regenerates identical tokens
        // from its own RNG stream.
        if !self.cfg.reserve && !self.active.is_empty() {
            loop {
                let need: usize = self
                    .active
                    .iter()
                    .map(|s| s.states[0].ctx_stage_cost() * self.kv_page_cost)
                    .sum();
                if self.fits_ctx(need) {
                    break;
                }
                if self.drop_lru_cache_entry() {
                    continue;
                }
                if self.active.len() <= 1 {
                    // a lone session always fits: validate() bounds its
                    // page-rounded horizon by max_tokens
                    break;
                }
                let mut slot = self.active.pop().expect("non-empty active set");
                let req = slot.request.take().expect("active slot keeps its request");
                for st in &mut slot.states {
                    st.release_pages();
                }
                // the discarded tokens will be regenerated after the
                // requeue, so they come off the generated count
                self.stats.generated -= slot.tokens.len();
                slot.tokens.clear();
                slot.logits.clear();
                self.pending.push_front(req);
                self.free.push(slot);
                self.stats.evictions += 1;
            }
            for slot in &mut self.active {
                for st in &mut slot.states[..n_states] {
                    st.stage_append();
                }
            }
        }
        let ps = self.pool.stats();
        self.stats.peak_ctx_tokens = self.stats.peak_ctx_tokens.max(ps.ctx_tokens());
        self.stats.peak_pages = self.stats.peak_pages.max(ps.live);

        // one ragged decode round across every active session; timed on
        // its own so the latency percentiles measure the same thing as
        // the sequential baseline's per-step samples (admission/prefill
        // time lands in wall_s and throughput, not in round latency)
        let n = self.active.len();
        if n > 0 {
            let t_round = Instant::now();
            match self.prefill.attn.pool() {
                Some(pool) if n > 1 => {
                    let workers = pool.size().min(n);
                    // deterministic contiguous split: chunk c covers
                    // active rows [c*n/workers, (c+1)*n/workers)
                    let mut jobs: Vec<(Vec<SessionSlot>, StepBuf)> = Vec::with_capacity(workers);
                    for c in (0..workers).rev() {
                        let lo = c * n / workers;
                        let mut chunk = self.chunk_store.pop().expect("chunk container");
                        chunk.clear();
                        chunk.extend(self.active.drain(lo..));
                        let buf = self.bufs.pop().expect("step buffer");
                        jobs.push((chunk, buf));
                    }
                    jobs.reverse();
                    let model = Arc::clone(&self.model);
                    let done = pool.map(jobs, move |(mut chunk, mut buf)| {
                        step_slots(model.as_ref(), &mut chunk, &mut buf);
                        (chunk, buf)
                    });
                    for (mut chunk, buf) in done {
                        self.active.append(&mut chunk);
                        self.chunk_store.push(chunk);
                        self.bufs.push(buf);
                    }
                }
                _ => {
                    step_slots(self.model.as_ref(), &mut self.active, &mut self.bufs[0]);
                }
            }
            self.stats.rounds += 1;
            self.stats.generated += n;
            self.stats.round_tokens.push(n);
            self.stats.round_s.push(t_round.elapsed().as_secs_f64());
            // eviction: retire finished sessions, preserving order
            let mut i = 0;
            while i < self.active.len() {
                if self.active[i].done {
                    let slot = self.active.remove(i);
                    self.retire(slot);
                } else {
                    i += 1;
                }
            }
        }
        self.stats.wall_s += t0.elapsed().as_secs_f64();
        !self.active.is_empty() || !self.pending.is_empty()
    }

    /// Submit every request and tick until the queue drains; returns
    /// the completions plus run stats (and resets both for the next
    /// run — the engine, its session pool, page pool and prefix cache
    /// are reusable). The whole batch is validated before anything is
    /// enqueued, so a rejected request leaves the engine exactly as it
    /// was — no half-queued workload leaking into the next run.
    pub fn run(&mut self, requests: Vec<Request>) -> Result<ServeReport, String> {
        for r in &requests {
            self.validate(r)?;
        }
        for r in requests {
            self.pending.push_back(r);
        }
        while self.tick() {}
        Ok(ServeReport {
            completions: std::mem::take(&mut self.completions),
            stats: std::mem::take(&mut self.stats),
        })
    }
}

/// The sequential baseline the serve acceptance compares against: one
/// session at a time through `Model::prefill_with` / `step`, recycling
/// a single `DecodeWorkspace` — identical request semantics and report
/// shape, so it doubles as the parity oracle for `tests/serve.rs`.
pub fn run_sequential(model: &Model, requests: &[Request]) -> Result<ServeReport, String> {
    run_sequential_dtype(model, requests, PageDtype::F32)
}

/// [`run_sequential`] with the sessions' KV pages stored as `kv_dtype`
/// — the one-at-a-time oracle for the engine's compressed-cache modes
/// (`htx serve-bench --kv-dtype` uses it as the parity reference).
pub fn run_sequential_dtype(
    model: &Model,
    requests: &[Request],
    kv_dtype: PageDtype,
) -> Result<ServeReport, String> {
    let mut ws = DecodeWorkspace::serial();
    ws.set_kv_dtype(kv_dtype);
    let mut completions = Vec::with_capacity(requests.len());
    let mut stats = ServeStats::default();
    let t_all = Instant::now();
    for req in requests {
        if req.max_new == 0 {
            return Err(format!("request {}: max_new must be >= 1", req.id));
        }
        let horizon = req.prompt.len().checked_add(req.max_new).ok_or_else(|| {
            format!("request {}: prompt + max_new overflows the context horizon", req.id)
        })?;
        if horizon > model.cfg.max_len {
            return Err(format!(
                "request {}: prompt {} + max_new {} exceeds model max_len {}",
                req.id,
                req.prompt.len(),
                req.max_new,
                model.cfg.max_len
            ));
        }
        let mut rng = Rng::new(req.seed);
        let mut session = model.prefill_with(ws, &req.prompt)?;
        stats.prefill_tokens += req.prompt.len();
        let mut tokens = Vec::with_capacity(req.max_new);
        let first = sample_logits(session.logits().row(0), req.temperature, &mut rng) as u32;
        tokens.push(first);
        stats.generated += 1;
        let mut next = first;
        let last_logits: Vec<f32> = if tokens.len() >= req.max_new {
            session.logits().row(0).to_vec()
        } else {
            loop {
                let ts = Instant::now();
                let logits = session.step(next)?;
                stats.round_s.push(ts.elapsed().as_secs_f64());
                stats.round_tokens.push(1);
                stats.rounds += 1;
                let t = sample_logits(logits.row(0), req.temperature, &mut rng) as u32;
                tokens.push(t);
                stats.generated += 1;
                if tokens.len() >= req.max_new {
                    break logits.row(0).to_vec();
                }
                next = t;
            }
        };
        completions.push(Completion {
            id: req.id,
            prompt_len: req.prompt.len(),
            tokens,
            last_logits,
            admitted_round: 0,
            finished_round: stats.rounds,
        });
        stats.peak_active = 1;
        ws = session.into_workspace();
    }
    stats.wall_s = t_all.elapsed().as_secs_f64();
    Ok(ServeReport { completions, stats })
}

/// Closed-loop synthetic workload: `n` requests whose prompt lengths
/// cycle through `prompt_mix`, sharing `max_new` and `temperature`,
/// with per-request RNG seeds derived from `seed`. All requests are
/// queued up front; admission paces them — the next stream starts as
/// soon as budget frees (the closed-loop serving regime). Prompt
/// tokens come from `util::bench::synthetic_prompt`, the generator
/// shared with the decode bench and `htx serve-bench`.
pub fn synthetic_workload(
    n: usize,
    prompt_mix: &[usize],
    max_new: usize,
    vocab: usize,
    temperature: f32,
    seed: u64,
) -> Vec<Request> {
    assert!(!prompt_mix.is_empty(), "prompt_mix must name at least one length");
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|i| {
            let pl = prompt_mix[i % prompt_mix.len()];
            Request {
                id: i as u64,
                prompt: synthetic_prompt(pl, vocab, &mut rng),
                max_new,
                temperature,
                seed: derive_seed(seed, i as u64),
            }
        })
        .collect()
}

/// Shared-system-prompt workload: `n` requests with one identical
/// `prompt_len`-token prompt (per-request RNG streams still distinct) —
/// the regime the prefix cache turns into an O(1)-per-duplicate
/// prefill with prompt pages allocated once.
pub fn shared_prefix_workload(
    n: usize,
    prompt_len: usize,
    max_new: usize,
    vocab: usize,
    temperature: f32,
    seed: u64,
) -> Vec<Request> {
    let mut rng = Rng::new(seed);
    let prompt = synthetic_prompt(prompt_len, vocab, &mut rng);
    (0..n)
        .map(|i| Request {
            id: i as u64,
            prompt: prompt.clone(),
            max_new,
            temperature,
            seed: derive_seed(seed, i as u64),
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{AttnSpec, ModelConfig};

    fn tiny_model(attention: AttnSpec, max_len: usize) -> Model {
        Model::new(
            ModelConfig {
                vocab_size: 29,
                d_model: 16,
                n_heads: 2,
                n_layers: 2,
                d_ff: 24,
                max_len,
                causal: true,
                attention,
                quant_weights: false,
            },
            7,
        )
        .unwrap()
    }

    #[test]
    fn compressed_kv_pages_admit_more_concurrent_sessions() {
        // the f32 shape of tight_token_budget_serialises_admissions:
        // each request grows to 4 pages; at page_len 4 and d_head 8 an
        // f32 page charges 4 tokens (16 per session — a 20-token budget
        // serialises), while an f16 page packs its 4x8 rows into 16
        // slots = 2 tokens (8 per session — two sessions fit)
        let model = Arc::new(tiny_model(AttnSpec::Full, 24));
        let mk = |kv_dtype| ServeConfig {
            max_batch: 4,
            max_tokens: 20,
            page_len: 4,
            threads: 1,
            kv_dtype,
            ..ServeConfig::default()
        };
        let reqs = synthetic_workload(4, &[9], 5, 29, 0.0, 3);
        let mut exact = ServeEngine::new(Arc::clone(&model), mk(PageDtype::F32)).unwrap();
        let rf = exact.run(reqs.clone()).unwrap();
        assert_eq!(rf.stats.peak_active, 1, "f32 baseline must serialise");
        let mut packed = ServeEngine::new(Arc::clone(&model), mk(PageDtype::F16)).unwrap();
        let rh = packed.run(reqs.clone()).unwrap();
        assert!(
            rh.stats.peak_active >= 2,
            "f16 KV should at least double concurrency, got {}",
            rh.stats.peak_active
        );
        assert!(rh.stats.peak_ctx_tokens <= 20, "budget exceeded");
        assert_eq!(rh.completions.len(), 4);
        // batched f16 decode matches the one-at-a-time f16 oracle
        let seq = run_sequential_dtype(&model, &reqs, PageDtype::F16).unwrap();
        assert_eq!(seq.tokens_by_id(), rh.tokens_by_id());
    }

    #[test]
    fn int8_kv_and_quantised_weights_still_serve() {
        // the lossiest configuration end to end: int8 KV pages plus
        // int8 weights, batched engine vs sequential oracle
        let model = Arc::new(
            Model::new(
                ModelConfig {
                    vocab_size: 29,
                    d_model: 16,
                    n_heads: 2,
                    n_layers: 2,
                    d_ff: 24,
                    max_len: 24,
                    causal: true,
                    attention: AttnSpec::H1d { nr: 4 },
                    quant_weights: true,
                },
                7,
            )
            .unwrap(),
        );
        let cfg = ServeConfig {
            max_batch: 3,
            kv_dtype: PageDtype::I8,
            threads: 1,
            ..ServeConfig::default()
        };
        let reqs = synthetic_workload(5, &[6, 9], 4, 29, 0.0, 21);
        let mut eng = ServeEngine::new(Arc::clone(&model), cfg).unwrap();
        let rep = eng.run(reqs.clone()).unwrap();
        assert_eq!(rep.completions.len(), 5);
        assert!(rep
            .completions
            .iter()
            .all(|c| c.last_logits.iter().all(|x| x.is_finite())));
        let seq = run_sequential_dtype(&model, &reqs, PageDtype::I8).unwrap();
        assert_eq!(seq.tokens_by_id(), rep.tokens_by_id());
    }

    #[test]
    fn submit_rejects_unrunnable_requests() {
        let model = Arc::new(tiny_model(AttnSpec::Full, 16));
        let mut eng = ServeEngine::new(
            Arc::clone(&model),
            ServeConfig {
                max_batch: 2,
                max_tokens: 32,
                threads: 1,
                ..ServeConfig::default()
            },
        )
        .unwrap();
        let ok = Request {
            id: 0,
            prompt: vec![1, 2, 3],
            max_new: 4,
            temperature: 0.0,
            seed: 1,
        };
        eng.submit(ok.clone()).unwrap();
        let mut bad = ok.clone();
        bad.prompt.clear();
        assert!(eng.submit(bad).unwrap_err().contains("empty prompt"));
        let mut bad = ok.clone();
        bad.max_new = 0;
        assert!(eng.submit(bad).unwrap_err().contains("max_new"));
        let mut bad = ok.clone();
        bad.max_new = 14; // 3 + 14 > max_len 16
        assert!(eng.submit(bad).unwrap_err().contains("max_len"));
        let mut bad = ok.clone();
        bad.prompt = vec![1; 18]; // longer than max_len outright
        assert!(eng.submit(bad).unwrap_err().contains("max_len"));
        let mut bad = ok.clone();
        bad.prompt = vec![0, 29]; // token id outside the vocabulary
        assert!(eng.submit(bad).unwrap_err().contains("vocab"));
        // prompt + max_new overflowing usize is rejected, not wrapped
        let mut bad = ok.clone();
        bad.max_new = usize::MAX;
        assert!(eng.submit(bad).unwrap_err().contains("overflows"));
        // a reservation within max_len but beyond the engine's whole
        // max_tokens budget can never be admitted: rejected at submit
        let mut eng2 = ServeEngine::new(
            model,
            ServeConfig {
                max_batch: 2,
                max_tokens: 6,
                page_len: 4,
                threads: 1,
                ..ServeConfig::default()
            },
        )
        .unwrap();
        assert!(eng2.submit(ok).unwrap_err().contains("max_tokens"));
    }

    #[test]
    fn engine_rejects_bad_page_len() {
        let model = Arc::new(tiny_model(AttnSpec::Full, 16));
        for bad in [0usize, 6, 12] {
            let err = ServeEngine::new(
                Arc::clone(&model),
                ServeConfig {
                    page_len: bad,
                    ..ServeConfig::default()
                },
            );
            assert!(err.is_err(), "page_len {bad} must be rejected");
        }
    }

    #[test]
    fn run_rejects_batches_atomically() {
        let model = Arc::new(tiny_model(AttnSpec::Full, 16));
        let mut eng = ServeEngine::new(Arc::clone(&model), ServeConfig::default()).unwrap();
        let mut reqs = synthetic_workload(3, &[4], 3, 29, 0.0, 1);
        reqs[2].prompt = vec![99]; // out-of-vocab, rejected at validation
        assert!(eng.run(reqs).is_err());
        assert_eq!(eng.queued(), 0, "a rejected batch must not enqueue anything");
        // the engine is still clean: a valid batch then runs normally
        let rep = eng.run(synthetic_workload(3, &[4], 3, 29, 0.0, 1)).unwrap();
        assert_eq!(rep.completions.len(), 3);
    }

    #[test]
    fn max_new_one_completes_at_prefill_without_a_round() {
        let model = Arc::new(tiny_model(AttnSpec::H1d { nr: 4 }, 16));
        let mut eng = ServeEngine::new(Arc::clone(&model), ServeConfig::default()).unwrap();
        let reqs = vec![Request {
            id: 9,
            prompt: vec![1, 2, 3, 4],
            max_new: 1,
            temperature: 0.0,
            seed: 5,
        }];
        let rep = eng.run(reqs.clone()).unwrap();
        assert_eq!(rep.stats.rounds, 0);
        assert_eq!(rep.stats.peak_active, 1, "prefill-only sessions still held a slot");
        assert_eq!(rep.completions.len(), 1);
        assert_eq!(rep.completions[0].tokens.len(), 1);
        // matches the sequential loop exactly
        let seq = run_sequential(&model, &reqs).unwrap();
        assert_eq!(seq.completions[0].tokens, rep.completions[0].tokens);
        assert_eq!(seq.completions[0].last_logits, rep.completions[0].last_logits);
    }

    #[test]
    fn tight_token_budget_serialises_admissions() {
        let model = Arc::new(tiny_model(AttnSpec::Full, 24));
        // each request can grow to ceil(14/4)*4 = 16 context tokens; a
        // 20-token budget fits one session at a time (two would need
        // >= 24), so the budget serialises the batch
        let mut eng = ServeEngine::new(
            model,
            ServeConfig {
                max_batch: 4,
                max_tokens: 20,
                page_len: 4,
                threads: 1,
                ..ServeConfig::default()
            },
        )
        .unwrap();
        let reqs = synthetic_workload(4, &[9], 5, 29, 0.0, 3);
        let rep = eng.run(reqs).unwrap();
        assert_eq!(rep.completions.len(), 4);
        assert_eq!(rep.stats.peak_active, 1, "budget should serialise sessions");
        assert_eq!(rep.stats.generated, 4 * 5);
        assert!(rep.stats.peak_ctx_tokens <= 20, "budget exceeded");
    }

    #[test]
    fn reserved_mode_matches_paged_results() {
        // the contiguous-reservation baseline and the paged engine are
        // the same scheduler over different memory policies: identical
        // workload results, different admission pacing
        let model = Arc::new(tiny_model(AttnSpec::H1d { nr: 4 }, 32));
        let reqs = synthetic_workload(6, &[7, 12], 6, 29, 0.0, 9);
        let mut paged = ServeEngine::new(Arc::clone(&model), ServeConfig::default()).unwrap();
        let mut reserved = ServeEngine::new(
            Arc::clone(&model),
            ServeConfig {
                reserve: true,
                ..ServeConfig::default()
            },
        )
        .unwrap();
        let rp = paged.run(reqs.clone()).unwrap();
        let rr = reserved.run(reqs).unwrap();
        assert_eq!(rp.tokens_by_id(), rr.tokens_by_id());
        assert_eq!(rr.stats.prefix_lookups, 0, "reserve mode disables the cache");
    }

    #[test]
    fn deeper_horizon_same_prompt_is_a_predicted_miss_and_replaces_the_entry() {
        // an entry cached at a shallow pyramid must never be *predicted*
        // as a free hit for a request needing a deeper one: the
        // admission accounting charges the full prefill (budget stays
        // sound), the hit path misses, and the re-prefill replaces the
        // entry at the deeper horizon so later twins hit again
        let model = Arc::new(tiny_model(AttnSpec::H1d { nr: 2 }, 28));
        let mut eng = ServeEngine::new(
            Arc::clone(&model),
            ServeConfig {
                max_batch: 2,
                // roomy enough that no eviction interferes: the pin here
                // is the predictor/hit-path agreement, not page pressure
                max_tokens: 48,
                page_len: 4,
                threads: 1,
                ..ServeConfig::default()
            },
        )
        .unwrap();
        let prompt: Vec<u32> = (0..6).map(|t| (t % 7) as u32).collect();
        let a = Request {
            id: 0,
            prompt: prompt.clone(),
            max_new: 2,
            temperature: 0.0,
            seed: 3,
        };
        // horizon 20 vs 8: decode_coarse_levels grows with the horizon,
        // so b needs a deeper pyramid than a's cached entry carries
        let b = Request {
            id: 1,
            prompt: prompt.clone(),
            max_new: 14,
            temperature: 0.0,
            seed: 4,
        };
        // same prompt and horizon as b: must hit b's replaced entry
        let c = Request {
            id: 2,
            prompt: prompt.clone(),
            max_new: 14,
            temperature: 0.0,
            seed: 5,
        };
        let reqs = vec![a, b, c];
        let rep = eng.run(reqs.clone()).unwrap();
        assert_eq!(rep.completions.len(), 3);
        assert_eq!(
            rep.stats.prefix_hits, 1,
            "only the equal-horizon twin may hit (deeper request must re-prefill)"
        );
        assert_eq!(rep.stats.prefill_tokens, 2 * 6);
        assert_eq!(rep.stats.evictions, 0);
        assert!(
            rep.stats.peak_ctx_tokens <= 48,
            "conservative prediction must keep the budget: peak {}",
            rep.stats.peak_ctx_tokens
        );
        let seq = run_sequential(&model, &reqs).unwrap();
        assert_eq!(seq.tokens_by_id(), rep.tokens_by_id());
    }

    #[test]
    fn synthetic_workload_cycles_the_mix() {
        let reqs = synthetic_workload(5, &[3, 7], 4, 29, 0.5, 11);
        assert_eq!(reqs.len(), 5);
        let lens: Vec<usize> = reqs.iter().map(|r| r.prompt.len()).collect();
        assert_eq!(lens, vec![3, 7, 3, 7, 3]);
        assert!(reqs.iter().all(|r| r.max_new == 4 && r.temperature == 0.5));
        assert!(reqs.iter().all(|r| r.prompt.iter().all(|&t| t < 29)));
        // distinct per-request seeds
        let mut seeds: Vec<u64> = reqs.iter().map(|r| r.seed).collect();
        seeds.sort_unstable();
        seeds.dedup();
        assert_eq!(seeds.len(), 5);
    }

    #[test]
    fn latency_percentiles_survive_zero_completion_runs() {
        // a run where every request is rejected at admission produces
        // stats with no decode rounds: the percentile rank math
        // ((len - 1) on an empty sample set) must be guarded, not hit
        let stats = ServeStats::default();
        assert_eq!(stats.try_latency_us(50.0), None);
        assert_eq!(stats.latency_us(50.0), 0.0);
        assert_eq!(stats.latency_us(99.0), 0.0);
        assert_eq!(stats.per_token_us(), 0.0);
        // the engine-level shape of the same case: submits all fail,
        // run() drains nothing, and the report's percentiles are 0.0
        let model = Arc::new(tiny_model(AttnSpec::Full, 16));
        let mut eng = ServeEngine::new(Arc::clone(&model), ServeConfig::default()).unwrap();
        let bad = Request {
            id: 0,
            prompt: vec![99], // out of vocab: rejected
            max_new: 2,
            temperature: 0.0,
            seed: 1,
        };
        assert!(eng.submit(bad).is_err());
        let rep = eng.run(Vec::new()).unwrap();
        assert!(rep.completions.is_empty());
        assert_eq!(rep.stats.try_latency_us(95.0), None);
        assert_eq!(rep.stats.latency_us(95.0), 0.0);
        // a one-round run clamps out-of-range pct instead of panicking
        let rep = eng.run(synthetic_workload(1, &[4], 2, 29, 0.0, 1)).unwrap();
        assert!(rep.stats.try_latency_us(200.0).is_some());
    }

    #[test]
    fn cancel_releases_pages_and_recycles_the_slot() {
        let model = Arc::new(tiny_model(AttnSpec::H1d { nr: 4 }, 32));
        let mut eng = ServeEngine::new(
            Arc::clone(&model),
            ServeConfig {
                max_batch: 2,
                prefix_cache: 0, // cache off so live pages pin to zero
                threads: 1,
                ..ServeConfig::default()
            },
        )
        .unwrap();
        let reqs = synthetic_workload(2, &[6], 8, 29, 0.0, 5);
        for r in reqs.clone() {
            eng.submit(r).unwrap();
        }
        // admit both and run a couple of rounds mid-stream
        eng.tick();
        eng.tick();
        assert_eq!(eng.active_sessions(), 2);
        let mut streamed = 0;
        eng.for_each_active(|_, toks| streamed += toks.len());
        assert!(streamed >= 2, "both sessions should have tokens by now");
        // cancel one mid-stream: pages released, no completion emitted
        assert!(eng.cancel(reqs[0].id));
        assert!(!eng.cancel(reqs[0].id), "double-cancel finds nothing");
        assert_eq!(eng.active_sessions(), 1);
        while eng.tick() {}
        let comps = eng.take_completions();
        assert_eq!(comps.len(), 1, "cancelled request must not complete");
        assert_eq!(comps[0].id, reqs[1].id);
        assert_eq!(eng.stats().cancelled, 1);
        assert_eq!(eng.pool_stats().live, 0, "cancel must release every page");
        // the survivor's tokens are unaffected by the cancellation
        let seq = run_sequential(&model, &reqs[1..]).unwrap();
        assert_eq!(seq.completions[0].tokens, comps[0].tokens);
        // a cancelled-then-identical workload reuses the recycled slot:
        // the workspace snapshot is invariant across cancel/re-admit
        let snap = eng.capacity_snapshot();
        for r in reqs.clone() {
            eng.submit(r).unwrap();
        }
        eng.tick();
        eng.tick();
        assert!(eng.cancel(reqs[0].id));
        while eng.tick() {}
        eng.take_completions();
        assert_eq!(eng.capacity_snapshot(), snap, "cancel path must not allocate");
    }

    #[test]
    fn cancel_pending_request_never_runs() {
        let model = Arc::new(tiny_model(AttnSpec::Full, 16));
        let mut eng = ServeEngine::new(Arc::clone(&model), ServeConfig::default()).unwrap();
        let reqs = synthetic_workload(2, &[4], 3, 29, 0.0, 9);
        for r in reqs.clone() {
            eng.submit(r).unwrap();
        }
        assert!(eng.cancel(reqs[1].id), "pending request is cancellable");
        assert_eq!(eng.queued(), 1);
        while eng.tick() {}
        let comps = eng.take_completions();
        assert_eq!(comps.len(), 1);
        assert_eq!(comps[0].id, reqs[0].id);
    }

    #[test]
    fn shared_prefix_workload_repeats_one_prompt() {
        let reqs = shared_prefix_workload(4, 6, 3, 29, 0.0, 17);
        assert_eq!(reqs.len(), 4);
        assert!(reqs.iter().all(|r| r.prompt == reqs[0].prompt));
        assert_eq!(reqs[0].prompt.len(), 6);
        let mut seeds: Vec<u64> = reqs.iter().map(|r| r.seed).collect();
        seeds.sort_unstable();
        seeds.dedup();
        assert_eq!(seeds.len(), 4);
    }
}
