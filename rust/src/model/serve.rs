//! Continuous-batching decode scheduler — the multi-session serving
//! layer over the KV-cached decode API, where the paper's O(L)
//! attention actually earns its keep: a server for heavy traffic must
//! interleave prefill and decode across many concurrent generation
//! streams, not run one `DecodeSession` at a time.
//!
//! ## Scheduler state machine
//!
//! A request moves `pending → active → completed` through
//! [`ServeEngine::tick`], which runs one scheduling round:
//!
//!  1. **Admission** — while the head of the FIFO queue fits both
//!     budgets (`max_batch` concurrent sessions, `max_tokens` summed
//!     `prompt + max_new` context reservation across active sessions),
//!     pop it, take a recycled slot from the session pool (or grow a
//!     fresh one), run **one batched prefill forward** over its prompt
//!     through the shared `ModelWorkspace` — the `run_trunk` observer
//!     bulk-loads every `(layer, head)` [`DecodeState`] — and sample
//!     its first token from the prefill logits.
//!  2. **Decode round** — every active session advances by one token
//!     through a ragged batched step: embeddings for all `n` sessions
//!     are assembled into `[n, D]` rows, each layer runs its LayerNorm
//!     / Q/K/V / output / FFN matmuls **once for the whole batch**
//!     (amortising every weight matrix read over `n` rows — the
//!     continuous-batching throughput win; a lone session re-streams
//!     the full parameter set per token), and attention goes through
//!     [`Attention::decode_step_batch`] — one call per layer, session
//!     `i`'s per-head states advancing against row `i`. With
//!     `threads > 1` the active set is split into contiguous chunks
//!     that run on the crate thread pool (slots and step buffers travel
//!     through `ThreadPool::map` by value, the workspace idiom).
//!  3. **Completion / eviction** — sessions that reached their
//!     `max_new` emit a [`Completion`] and their slot (KV arena, token
//!     and logits buffers included) returns to the pool for the next
//!     admission; `prompt + max_new`-shaped re-admissions re-use the
//!     arena without growing it.
//!
//! ## Ragged-batch layout
//!
//! Active sessions sit at different context lengths; nothing is padded.
//! Session `i` contributes row `i` of every `[n, ·]` activation matrix,
//! and its per-`(layer, head)` `DecodeState`s advance independently —
//! `decode_step_batch` receives the states session-major, head `h` of
//! the `[n, H·d]` projection rows at columns `h*d..(h+1)*d`. Because
//! every per-row computation is independent and loop orders match the
//! single-session step path, batched logits are **bitwise** what a lone
//! `DecodeSession` produces — `tests/serve.rs` pins batched-vs-
//! sequential parity at 1e-5 and determinism under arrival-order
//! permutations.
//!
//! ## Budget knobs ([`ServeConfig`])
//!
//! * `max_batch` — concurrent-session cap (compute bound per round);
//! * `max_tokens` — summed context reservation (`prompt + max_new`)
//!   across active sessions (KV-memory bound; a request that could
//!   never fit is rejected at [`ServeEngine::submit`]);
//! * `threads` — worker count for prefill head dispatch and chunked
//!   decode rounds (`<= 1` runs on the calling thread).
//!
//! Entry points: `htx serve-bench` (closed-loop synthetic workload),
//! `benches/serve.rs` (emits `BENCH_serve.json`, the CI perf
//! trajectory), `examples/cpu_serve.rs`.

use std::collections::VecDeque;
use std::sync::Arc;
use std::time::Instant;

use super::{sample_logits, DecodeWorkspace, Model, ModelWorkspace, LN_EPS};
use crate::attention::DecodeState;
use crate::tensor::ops::{add_assign, add_bias_rows, gelu, layernorm_rows_into, matmul_into};
use crate::tensor::Mat;
use crate::util::Rng;

/// Scheduler budgets; see the module docs.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Maximum concurrently active sessions per round.
    pub max_batch: usize,
    /// Maximum summed context reservation (`prompt + max_new`) across
    /// active sessions — the KV-memory budget.
    pub max_tokens: usize,
    /// Worker threads for prefill and chunked decode rounds
    /// (`<= 1` means the calling thread).
    pub threads: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        Self {
            max_batch: 8,
            max_tokens: usize::MAX,
            threads: 1,
        }
    }
}

/// One generation request: a prompt, a token budget and per-request
/// sampling parameters (greedy at `temperature <= 0`, otherwise a
/// seeded softmax draw — each request owns its RNG stream, so results
/// are independent of batch composition).
#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<u32>,
    /// Tokens to generate (>= 1); the first is sampled from the
    /// prefill logits, exactly like the sequential `htx generate` loop.
    pub max_new: usize,
    pub temperature: f32,
    pub seed: u64,
}

/// A finished request: the generated tokens plus the `[vocab]` logits
/// of the final generated position (the parity pin for tests).
#[derive(Clone, Debug)]
pub struct Completion {
    pub id: u64,
    pub prompt_len: usize,
    pub tokens: Vec<u32>,
    pub last_logits: Vec<f32>,
    /// Round index at which the request was admitted / finished. Once
    /// admitted a session produces one token per round, so these mark
    /// *when* the request held a slot; queueing delay before admission
    /// is visible engine-wide as rounds where `queued() > 0`.
    pub admitted_round: usize,
    pub finished_round: usize,
}

/// Aggregate serving metrics for one run.
#[derive(Clone, Debug, Default)]
pub struct ServeStats {
    /// Decode rounds executed.
    pub rounds: usize,
    /// Tokens generated (prefill-sampled first tokens included).
    pub generated: usize,
    /// Prompt tokens prefilled.
    pub prefill_tokens: usize,
    /// Total wall time across ticks (admission + rounds), seconds.
    pub wall_s: f64,
    /// Wall time of each decode round. Admission/prefill time is
    /// excluded (it shows up in `wall_s` and therefore throughput), so
    /// the p50/p95 derived from these samples measures the same thing
    /// as the sequential baseline's per-`step` samples.
    pub round_s: Vec<f64>,
    /// Tokens produced by each round (= active sessions that round).
    pub round_tokens: Vec<usize>,
    /// Peak concurrently active sessions.
    pub peak_active: usize,
}

impl ServeStats {
    /// Aggregate throughput: generated tokens per wall second.
    pub fn tokens_per_sec(&self) -> f64 {
        if self.wall_s > 0.0 {
            self.generated as f64 / self.wall_s
        } else {
            0.0
        }
    }

    /// Aggregate per-token cost in µs (`wall / generated`) — the
    /// regression-gate metric of `BENCH_serve.json`.
    pub fn per_token_us(&self) -> f64 {
        if self.generated > 0 {
            self.wall_s * 1e6 / self.generated as f64
        } else {
            0.0
        }
    }

    /// Per-token latency percentile in µs: every token generated in a
    /// round observes that round's wall time (`pct` in 0..=100).
    pub fn latency_us(&self, pct: f64) -> f64 {
        let mut samples: Vec<f64> = Vec::new();
        for (s, n) in self.round_s.iter().zip(&self.round_tokens) {
            samples.extend(std::iter::repeat(*s * 1e6).take(*n));
        }
        if samples.is_empty() {
            return 0.0;
        }
        samples.sort_by(|a, b| a.partial_cmp(b).expect("finite latencies"));
        let idx = ((pct / 100.0) * (samples.len() - 1) as f64).round() as usize;
        samples[idx.min(samples.len() - 1)]
    }

    /// Mean active sessions per decode round (batch fill).
    pub fn mean_occupancy(&self) -> f64 {
        if self.round_tokens.is_empty() {
            0.0
        } else {
            self.round_tokens.iter().sum::<usize>() as f64 / self.round_tokens.len() as f64
        }
    }
}

/// Completions plus run-level stats — returned by both
/// [`ServeEngine::run`] and the [`run_sequential`] baseline.
#[derive(Clone, Debug)]
pub struct ServeReport {
    pub completions: Vec<Completion>,
    pub stats: ServeStats,
}

impl ServeReport {
    /// Generated tokens keyed and sorted by request id — the
    /// scheduling-invariant view two runs of one workload must agree
    /// on. The parity guard shared by `htx serve-bench`,
    /// `benches/serve.rs` and the test suite: batching, chunking and
    /// arrival order may change *when* a request runs, never *what* it
    /// generates.
    pub fn tokens_by_id(&self) -> Vec<(u64, &[u32])> {
        let mut out: Vec<(u64, &[u32])> = self
            .completions
            .iter()
            .map(|c| (c.id, c.tokens.as_slice()))
            .collect();
        out.sort_by_key(|(id, _)| *id);
        out
    }
}

/// One pooled session: the per-`(layer, head)` KV states plus request
/// bookkeeping. Slots recycle through the engine's free pool — all
/// buffers are grow-only, so same-shape re-admissions allocate nothing.
struct SessionSlot {
    id: u64,
    prompt_len: usize,
    max_new: usize,
    /// `prompt + max_new`, the admission-budget reservation.
    budget: usize,
    temperature: f32,
    rng: Rng,
    /// Tokens consumed so far = position the next fed token decodes at.
    pos: usize,
    /// Last sampled token, fed in the next round.
    next_token: u32,
    /// Generated tokens (capacity reserved to `max_new` at admission).
    tokens: Vec<u32>,
    /// `[vocab]` logits of the final generated position, filled at
    /// completion (capacity reserved at admission).
    logits: Vec<f32>,
    /// `layer * n_heads + head` order, like `DecodeWorkspace`.
    states: Vec<DecodeState>,
    admitted_round: usize,
    done: bool,
}

impl SessionSlot {
    fn fresh() -> Self {
        Self {
            id: 0,
            prompt_len: 0,
            max_new: 0,
            budget: 0,
            temperature: 0.0,
            rng: Rng::new(0),
            pos: 0,
            next_token: 0,
            tokens: Vec::new(),
            logits: Vec::new(),
            states: Vec::new(),
            admitted_round: 0,
            done: false,
        }
    }
}

/// Per-worker activation buffers for one chunk of a decode round —
/// the `[n, ·]` counterpart of the `[1, ·]` buffers in
/// `DecodeWorkspace`. Grow-only, recycled round to round.
#[derive(Default)]
struct StepBuf {
    x: Mat,
    hn: Mat,
    q: Mat,
    k: Mat,
    v: Mat,
    merged: Mat,
    proj: Mat,
    ff: Mat,
    logits: Mat,
}

impl StepBuf {
    fn snapshot(&self) -> Vec<(usize, usize)> {
        [
            &self.x,
            &self.hn,
            &self.q,
            &self.k,
            &self.v,
            &self.merged,
            &self.proj,
            &self.ff,
            &self.logits,
        ]
        .iter()
        .map(|m| (m.data.as_ptr() as usize, m.data.capacity()))
        .collect()
    }
}

/// One ragged decode round over `slots`: embed every session's pending
/// token at its own position, run each layer's batched matmuls once for
/// the chunk, advance all per-head caches through
/// `Attention::decode_step_batch`, then sample each session's next
/// token from the batched logits. Row `i` is bitwise the
/// single-session step path (loop orders match; every per-row op reads
/// only row `i`).
///
/// KEEP IN SYNC with `DecodeSession::step` (decode.rs): this is that
/// layer schedule at `[n, D]` instead of `[1, D]`, differing only in
/// `decode_step_batch` vs per-head `decode_step`. Any change to the
/// block structure must land in both; `tests/serve.rs` pins the parity
/// at 1e-5 so drift fails loudly.
fn step_slots(model: &Model, slots: &mut [SessionSlot], buf: &mut StepBuf) {
    if slots.is_empty() {
        return;
    }
    let cfg = &model.cfg;
    let p = &model.params;
    let n = slots.len();
    let (d, n_heads) = (cfg.d_model, cfg.n_heads);
    let n_states = cfg.n_layers * n_heads;

    // token + positional embedding for every session's current position
    buf.x.reset_for_overwrite(n, d);
    for (i, slot) in slots.iter().enumerate() {
        debug_assert!(
            slot.states[..n_states].iter().all(|st| st.remaining() > 0),
            "session {} stepped beyond its reserved context",
            slot.id
        );
        let row = buf.x.row_mut(i);
        for ((o, e), ps) in row
            .iter_mut()
            .zip(p.embed.row(slot.next_token as usize))
            .zip(p.pos.row(slot.pos))
        {
            *o = e + ps;
        }
    }

    for (layer, lp) in p.layers.iter().enumerate() {
        // pre-LN attention block at [n, D]; one weight read per matrix
        layernorm_rows_into(&buf.x, &lp.ln1_scale, &lp.ln1_bias, LN_EPS, &mut buf.hn);
        matmul_into(&buf.hn, &lp.wq, &mut buf.q);
        matmul_into(&buf.hn, &lp.wk, &mut buf.k);
        matmul_into(&buf.hn, &lp.wv, &mut buf.v);
        buf.merged.reset_for_overwrite(n, d);
        let mut layer_states: Vec<&mut [DecodeState]> = slots
            .iter_mut()
            .map(|s| &mut s.states[layer * n_heads..(layer + 1) * n_heads])
            .collect();
        model.algo.decode_step_batch(
            &mut layer_states,
            &buf.q,
            &buf.k,
            &buf.v,
            cfg.causal,
            &mut buf.merged,
        );
        matmul_into(&buf.merged, &lp.wo, &mut buf.proj);
        add_assign(&mut buf.x, &buf.proj);

        // pre-LN feed-forward block
        layernorm_rows_into(&buf.x, &lp.ln2_scale, &lp.ln2_bias, LN_EPS, &mut buf.hn);
        matmul_into(&buf.hn, &lp.ff_w1, &mut buf.ff);
        add_bias_rows(&mut buf.ff, &lp.ff_b1);
        gelu(&mut buf.ff);
        matmul_into(&buf.ff, &lp.ff_w2, &mut buf.proj);
        add_bias_rows(&mut buf.proj, &lp.ff_b2);
        add_assign(&mut buf.x, &buf.proj);
    }

    model.logits_into(&buf.x, &mut buf.hn, &mut buf.logits);
    for (i, slot) in slots.iter_mut().enumerate() {
        slot.pos += 1;
        let row = buf.logits.row(i);
        let t = sample_logits(row, slot.temperature, &mut slot.rng) as u32;
        slot.tokens.push(t);
        if slot.tokens.len() >= slot.max_new {
            slot.done = true;
            slot.logits.clear();
            slot.logits.extend_from_slice(row);
        } else {
            slot.next_token = t;
        }
    }
}

/// The continuous-batching scheduler; see the module docs. Owns the
/// model through an `Arc` so chunked rounds can travel through the
/// thread pool's `'static` jobs.
pub struct ServeEngine {
    model: Arc<Model>,
    cfg: ServeConfig,
    /// Shared batched-forward arena for admission prefills; its
    /// attention pool doubles as the decode-round worker pool (one set
    /// of OS threads per engine — prefill and rounds never overlap).
    prefill: ModelWorkspace,
    /// `[1, ·]` admission head-logits path (first-token sampling).
    adm_x: Mat,
    adm_hn: Mat,
    adm_logits: Mat,
    pending: VecDeque<Request>,
    active: Vec<SessionSlot>,
    /// Session pool: retired slots waiting to be re-admitted.
    free: Vec<SessionSlot>,
    /// Reusable chunk containers for pooled rounds (one per worker).
    chunk_store: Vec<Vec<SessionSlot>>,
    /// Per-worker step buffers.
    bufs: Vec<StepBuf>,
    completions: Vec<Completion>,
    stats: ServeStats,
    /// Summed `budget` of active sessions (admission accounting).
    active_budget: usize,
}

impl ServeEngine {
    pub fn new(model: Arc<Model>, cfg: ServeConfig) -> Result<ServeEngine, String> {
        if cfg.max_batch == 0 {
            return Err("max_batch must be >= 1".to_string());
        }
        if cfg.max_tokens == 0 {
            return Err("max_tokens budget must be >= 1".to_string());
        }
        let threads = cfg.threads.max(1);
        Ok(ServeEngine {
            prefill: ModelWorkspace::new(threads),
            adm_x: Mat::default(),
            adm_hn: Mat::default(),
            adm_logits: Mat::default(),
            pending: VecDeque::new(),
            active: Vec::with_capacity(cfg.max_batch),
            free: Vec::with_capacity(cfg.max_batch),
            chunk_store: (0..threads).map(|_| Vec::with_capacity(cfg.max_batch)).collect(),
            bufs: (0..threads).map(|_| StepBuf::default()).collect(),
            completions: Vec::new(),
            stats: ServeStats::default(),
            active_budget: 0,
            model,
            cfg,
        })
    }

    /// Validate and enqueue a request (FIFO). Rejects requests that
    /// could never run: empty prompt, `max_new == 0`, token ids outside
    /// the vocabulary, or a context reservation exceeding the model's
    /// `max_len` or the engine's `max_tokens` budget.
    pub fn submit(&mut self, req: Request) -> Result<(), String> {
        self.validate(&req)?;
        self.pending.push_back(req);
        Ok(())
    }

    /// The [`ServeEngine::submit`] admission checks, side-effect free.
    fn validate(&self, req: &Request) -> Result<(), String> {
        let mcfg = &self.model.cfg;
        if req.prompt.is_empty() {
            return Err(format!("request {}: empty prompt", req.id));
        }
        if req.max_new == 0 {
            return Err(format!("request {}: max_new must be >= 1", req.id));
        }
        let budget = req.prompt.len() + req.max_new;
        if budget > mcfg.max_len {
            return Err(format!(
                "request {}: prompt {} + max_new {} exceeds model max_len {}",
                req.id,
                req.prompt.len(),
                req.max_new,
                mcfg.max_len
            ));
        }
        if budget > self.cfg.max_tokens {
            return Err(format!(
                "request {}: context reservation {budget} exceeds the max_tokens budget {}",
                req.id, self.cfg.max_tokens
            ));
        }
        if let Some(&bad) = req.prompt.iter().find(|&&t| t as usize >= mcfg.vocab_size) {
            return Err(format!(
                "request {}: token id {bad} >= vocab {}",
                req.id, mcfg.vocab_size
            ));
        }
        Ok(())
    }

    /// Queued requests not yet admitted.
    pub fn queued(&self) -> usize {
        self.pending.len()
    }

    /// Currently active sessions.
    pub fn active_sessions(&self) -> usize {
        self.active.len()
    }

    /// Run-so-far metrics (reset by [`ServeEngine::run`]).
    pub fn stats(&self) -> &ServeStats {
        &self.stats
    }

    /// Completions accumulated so far (drains the internal buffer).
    pub fn take_completions(&mut self) -> Vec<Completion> {
        std::mem::take(&mut self.completions)
    }

    /// `(pointer, capacity)` of every workspace buffer the engine owns
    /// — session slots (active and pooled), step buffers, the prefill
    /// arena and the admission head path. Sorted, so the snapshot is
    /// invariant to slots migrating between the active set and the
    /// pool; equal snapshots across ticks prove the steady state
    /// allocates nothing in any workspace (request outputs — completion
    /// token/logit copies — are not workspace and are excluded).
    pub fn capacity_snapshot(&self) -> Vec<(usize, usize)> {
        let mut out: Vec<(usize, usize)> = Vec::new();
        for slot in self.active.iter().chain(self.free.iter()) {
            out.push((slot.states.as_ptr() as usize, slot.states.capacity()));
            for st in &slot.states {
                out.extend(st.buffer_snapshot());
            }
            out.push((slot.tokens.as_ptr() as usize, slot.tokens.capacity()));
            out.push((slot.logits.as_ptr() as usize, slot.logits.capacity()));
        }
        for b in &self.bufs {
            out.extend(b.snapshot());
        }
        for c in &self.chunk_store {
            out.push((c.as_ptr() as usize, c.capacity()));
        }
        out.extend(self.prefill.capacity_snapshot());
        for m in [&self.adm_x, &self.adm_hn, &self.adm_logits] {
            out.push((m.data.as_ptr() as usize, m.data.capacity()));
        }
        out.sort_unstable();
        out
    }

    /// Admit one request into a (recycled) session slot: reset and
    /// reserve its per-`(layer, head)` states to the request's own
    /// horizon, run the batched prefill forward, and sample the first
    /// token from the prefill logits. A request whose `max_new` is 1
    /// completes here and never enters a decode round.
    ///
    /// KEEP IN SYNC with `Model::prefill_with` (decode.rs): same
    /// state-reserve + `run_trunk` observer sequence, pooled instead of
    /// per-`DecodeWorkspace` (the one semantic difference: states are
    /// reserved to the request horizon, not `max_len` — h1d's step
    /// output is invariant to the extra pyramid depth).
    fn admit(&mut self, req: Request) {
        let model = Arc::clone(&self.model);
        let mcfg = &model.cfg;
        let n_heads = mcfg.n_heads;
        let n_states = mcfg.n_layers * n_heads;
        let mut slot = self.free.pop().unwrap_or_else(SessionSlot::fresh);
        slot.id = req.id;
        slot.prompt_len = req.prompt.len();
        slot.max_new = req.max_new;
        slot.budget = req.prompt.len() + req.max_new;
        slot.temperature = req.temperature;
        slot.rng = Rng::new(req.seed);
        slot.pos = req.prompt.len();
        slot.tokens.clear();
        slot.tokens.reserve(req.max_new);
        slot.logits.clear();
        slot.logits.reserve(mcfg.vocab_size);
        slot.admitted_round = self.stats.rounds;
        slot.done = false;
        while slot.states.len() < n_states {
            slot.states.push(DecodeState::default());
        }
        for st in &mut slot.states[..n_states] {
            model.algo.decode_begin(st, slot.budget, mcfg.d_head());
        }

        // one batched forward over the prompt; the observer bulk-loads
        // every (layer, head) cache — the decode.rs prefill, pooled
        let states = &mut slot.states;
        model.run_trunk(&mut self.prefill, &req.prompt, 1, |layer, qkv| {
            for h in 0..n_heads {
                model.algo.decode_load_prefix(
                    &mut states[layer * n_heads + h],
                    qkv.q.head(h),
                    qkv.k.head(h),
                    qkv.v.head(h),
                );
            }
        });

        // first-token logits from the last prompt position
        self.adm_x.reset_for_overwrite(1, mcfg.d_model);
        self.adm_x
            .row_mut(0)
            .copy_from_slice(self.prefill.x.row(req.prompt.len() - 1));
        model.logits_into(&self.adm_x, &mut self.adm_hn, &mut self.adm_logits);
        let row = self.adm_logits.row(0);
        let t = sample_logits(row, slot.temperature, &mut slot.rng) as u32;
        slot.tokens.push(t);
        self.stats.prefill_tokens += req.prompt.len();
        self.stats.generated += 1;
        if slot.tokens.len() >= slot.max_new {
            slot.done = true;
            slot.logits.clear();
            slot.logits.extend_from_slice(row);
            // the session held a slot during its prefill even though it
            // never enters a decode round — count it as active
            self.stats.peak_active = self.stats.peak_active.max(self.active.len() + 1);
            self.retire(slot);
        } else {
            slot.next_token = t;
            self.active_budget += slot.budget;
            self.active.push(slot);
            self.stats.peak_active = self.stats.peak_active.max(self.active.len());
        }
    }

    /// Emit a [`Completion`] and recycle the slot into the pool. The
    /// slot keeps its buffers (token/logit copies go to the completion)
    /// so a same-shape re-admission allocates nothing.
    fn retire(&mut self, mut slot: SessionSlot) {
        self.completions.push(Completion {
            id: slot.id,
            prompt_len: slot.prompt_len,
            tokens: slot.tokens.clone(),
            last_logits: slot.logits.clone(),
            admitted_round: slot.admitted_round,
            finished_round: self.stats.rounds,
        });
        slot.tokens.clear();
        slot.logits.clear();
        self.free.push(slot);
    }

    /// One scheduling round: admit what fits, run one ragged decode
    /// round over the active set, retire finished sessions. Returns
    /// whether work remains (pending or active requests).
    pub fn tick(&mut self) -> bool {
        let t0 = Instant::now();
        // admission: head-of-line FIFO within both budgets (a request's
        // fit is checked at submit, so an empty active set always admits)
        while self.active.len() < self.cfg.max_batch {
            let fits = match self.pending.front() {
                None => false,
                Some(r) => {
                    self.active_budget + r.prompt.len() + r.max_new <= self.cfg.max_tokens
                }
            };
            if !fits {
                break;
            }
            let req = self.pending.pop_front().expect("checked front");
            self.admit(req);
        }

        // one ragged decode round across every active session; timed on
        // its own so the latency percentiles measure the same thing as
        // the sequential baseline's per-step samples (admission/prefill
        // time lands in wall_s and throughput, not in round latency)
        let n = self.active.len();
        if n > 0 {
            let t_round = Instant::now();
            match self.prefill.attn.pool() {
                Some(pool) if n > 1 => {
                    let workers = pool.size().min(n);
                    // deterministic contiguous split: chunk c covers
                    // active rows [c*n/workers, (c+1)*n/workers)
                    let mut jobs: Vec<(Vec<SessionSlot>, StepBuf)> = Vec::with_capacity(workers);
                    for c in (0..workers).rev() {
                        let lo = c * n / workers;
                        let mut chunk = self.chunk_store.pop().expect("chunk container");
                        chunk.clear();
                        chunk.extend(self.active.drain(lo..));
                        let buf = self.bufs.pop().expect("step buffer");
                        jobs.push((chunk, buf));
                    }
                    jobs.reverse();
                    let model = Arc::clone(&self.model);
                    let done = pool.map(jobs, move |(mut chunk, mut buf)| {
                        step_slots(model.as_ref(), &mut chunk, &mut buf);
                        (chunk, buf)
                    });
                    for (mut chunk, buf) in done {
                        self.active.append(&mut chunk);
                        self.chunk_store.push(chunk);
                        self.bufs.push(buf);
                    }
                }
                _ => {
                    step_slots(self.model.as_ref(), &mut self.active, &mut self.bufs[0]);
                }
            }
            self.stats.rounds += 1;
            self.stats.generated += n;
            self.stats.round_tokens.push(n);
            self.stats.round_s.push(t_round.elapsed().as_secs_f64());
            // eviction: retire finished sessions, preserving order
            let mut i = 0;
            while i < self.active.len() {
                if self.active[i].done {
                    let slot = self.active.remove(i);
                    self.active_budget -= slot.budget;
                    self.retire(slot);
                } else {
                    i += 1;
                }
            }
        }
        self.stats.wall_s += t0.elapsed().as_secs_f64();
        !self.active.is_empty() || !self.pending.is_empty()
    }

    /// Submit every request and tick until the queue drains; returns
    /// the completions plus run stats (and resets both for the next
    /// run — the engine and its session pool are reusable). The whole
    /// batch is validated before anything is enqueued, so a rejected
    /// request leaves the engine exactly as it was — no half-queued
    /// workload leaking into the next run.
    pub fn run(&mut self, requests: Vec<Request>) -> Result<ServeReport, String> {
        for r in &requests {
            self.validate(r)?;
        }
        for r in requests {
            self.pending.push_back(r);
        }
        while self.tick() {}
        Ok(ServeReport {
            completions: std::mem::take(&mut self.completions),
            stats: std::mem::take(&mut self.stats),
        })
    }
}

/// The sequential baseline the serve acceptance compares against: one
/// session at a time through `Model::prefill_with` / `step`, recycling
/// a single `DecodeWorkspace` — identical request semantics and report
/// shape, so it doubles as the parity oracle for `tests/serve.rs`.
pub fn run_sequential(model: &Model, requests: &[Request]) -> Result<ServeReport, String> {
    let mut ws = DecodeWorkspace::serial();
    let mut completions = Vec::with_capacity(requests.len());
    let mut stats = ServeStats::default();
    let t_all = Instant::now();
    for req in requests {
        if req.max_new == 0 {
            return Err(format!("request {}: max_new must be >= 1", req.id));
        }
        if req.prompt.len() + req.max_new > model.cfg.max_len {
            return Err(format!(
                "request {}: prompt {} + max_new {} exceeds model max_len {}",
                req.id,
                req.prompt.len(),
                req.max_new,
                model.cfg.max_len
            ));
        }
        let mut rng = Rng::new(req.seed);
        let mut session = model.prefill_with(ws, &req.prompt)?;
        stats.prefill_tokens += req.prompt.len();
        let mut tokens = Vec::with_capacity(req.max_new);
        let first = sample_logits(session.logits().row(0), req.temperature, &mut rng) as u32;
        tokens.push(first);
        stats.generated += 1;
        let mut next = first;
        let last_logits: Vec<f32> = if tokens.len() >= req.max_new {
            session.logits().row(0).to_vec()
        } else {
            loop {
                let ts = Instant::now();
                let logits = session.step(next)?;
                stats.round_s.push(ts.elapsed().as_secs_f64());
                stats.round_tokens.push(1);
                stats.rounds += 1;
                let t = sample_logits(logits.row(0), req.temperature, &mut rng) as u32;
                tokens.push(t);
                stats.generated += 1;
                if tokens.len() >= req.max_new {
                    break logits.row(0).to_vec();
                }
                next = t;
            }
        };
        completions.push(Completion {
            id: req.id,
            prompt_len: req.prompt.len(),
            tokens,
            last_logits,
            admitted_round: 0,
            finished_round: stats.rounds,
        });
        stats.peak_active = 1;
        ws = session.into_workspace();
    }
    stats.wall_s = t_all.elapsed().as_secs_f64();
    Ok(ServeReport { completions, stats })
}

/// Closed-loop synthetic workload: `n` requests whose prompt lengths
/// cycle through `prompt_mix`, sharing `max_new` and `temperature`,
/// with per-request RNG seeds derived from `seed`. All requests are
/// queued up front; admission paces them — the next stream starts as
/// soon as budget frees (the closed-loop serving regime). Behind
/// `htx serve-bench`, `benches/serve.rs` and the parity tests.
pub fn synthetic_workload(
    n: usize,
    prompt_mix: &[usize],
    max_new: usize,
    vocab: usize,
    temperature: f32,
    seed: u64,
) -> Vec<Request> {
    assert!(!prompt_mix.is_empty(), "prompt_mix must name at least one length");
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|i| {
            let pl = prompt_mix[i % prompt_mix.len()];
            Request {
                id: i as u64,
                prompt: (0..pl).map(|_| rng.below(vocab as u64) as u32).collect(),
                max_new,
                temperature,
                seed: seed ^ ((i as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15)),
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{AttnSpec, ModelConfig};

    fn tiny_model(attention: AttnSpec, max_len: usize) -> Model {
        Model::new(
            ModelConfig {
                vocab_size: 29,
                d_model: 16,
                n_heads: 2,
                n_layers: 2,
                d_ff: 24,
                max_len,
                causal: true,
                attention,
            },
            7,
        )
        .unwrap()
    }

    #[test]
    fn submit_rejects_unrunnable_requests() {
        let model = Arc::new(tiny_model(AttnSpec::Full, 16));
        let mut eng = ServeEngine::new(
            Arc::clone(&model),
            ServeConfig {
                max_batch: 2,
                max_tokens: 20,
                threads: 1,
            },
        )
        .unwrap();
        let ok = Request {
            id: 0,
            prompt: vec![1, 2, 3],
            max_new: 4,
            temperature: 0.0,
            seed: 1,
        };
        eng.submit(ok.clone()).unwrap();
        let mut bad = ok.clone();
        bad.prompt.clear();
        assert!(eng.submit(bad).unwrap_err().contains("empty prompt"));
        let mut bad = ok.clone();
        bad.max_new = 0;
        assert!(eng.submit(bad).unwrap_err().contains("max_new"));
        let mut bad = ok.clone();
        bad.max_new = 14; // 3 + 14 > max_len 16
        assert!(eng.submit(bad).unwrap_err().contains("max_len"));
        let mut bad = ok.clone();
        bad.prompt = vec![1; 18]; // longer than max_len outright
        assert!(eng.submit(bad).unwrap_err().contains("max_len"));
        let mut bad = ok.clone();
        bad.prompt = vec![0, 29]; // token id outside the vocabulary
        assert!(eng.submit(bad).unwrap_err().contains("vocab"));
        // a reservation within max_len but beyond the engine's whole
        // max_tokens budget can never be admitted: rejected at submit
        let mut eng2 = ServeEngine::new(
            model,
            ServeConfig {
                max_batch: 2,
                max_tokens: 6,
                threads: 1,
            },
        )
        .unwrap();
        assert!(eng2.submit(ok).unwrap_err().contains("max_tokens"));
    }

    #[test]
    fn run_rejects_batches_atomically() {
        let model = Arc::new(tiny_model(AttnSpec::Full, 16));
        let mut eng = ServeEngine::new(Arc::clone(&model), ServeConfig::default()).unwrap();
        let mut reqs = synthetic_workload(3, &[4], 3, 29, 0.0, 1);
        reqs[2].prompt = vec![99]; // out-of-vocab, rejected at validation
        assert!(eng.run(reqs).is_err());
        assert_eq!(eng.queued(), 0, "a rejected batch must not enqueue anything");
        // the engine is still clean: a valid batch then runs normally
        let rep = eng.run(synthetic_workload(3, &[4], 3, 29, 0.0, 1)).unwrap();
        assert_eq!(rep.completions.len(), 3);
    }

    #[test]
    fn max_new_one_completes_at_prefill_without_a_round() {
        let model = Arc::new(tiny_model(AttnSpec::H1d { nr: 4 }, 16));
        let mut eng = ServeEngine::new(Arc::clone(&model), ServeConfig::default()).unwrap();
        let reqs = vec![Request {
            id: 9,
            prompt: vec![1, 2, 3, 4],
            max_new: 1,
            temperature: 0.0,
            seed: 5,
        }];
        let rep = eng.run(reqs.clone()).unwrap();
        assert_eq!(rep.stats.rounds, 0);
        assert_eq!(rep.stats.peak_active, 1, "prefill-only sessions still held a slot");
        assert_eq!(rep.completions.len(), 1);
        assert_eq!(rep.completions[0].tokens.len(), 1);
        // matches the sequential loop exactly
        let seq = run_sequential(&model, &reqs).unwrap();
        assert_eq!(seq.completions[0].tokens, rep.completions[0].tokens);
        assert_eq!(seq.completions[0].last_logits, rep.completions[0].last_logits);
    }

    #[test]
    fn tight_token_budget_serialises_admissions() {
        let model = Arc::new(tiny_model(AttnSpec::Full, 24));
        // each request reserves 9 + 5 = 14; a 20-token budget fits one
        let mut eng = ServeEngine::new(
            model,
            ServeConfig {
                max_batch: 4,
                max_tokens: 20,
                threads: 1,
            },
        )
        .unwrap();
        let reqs = synthetic_workload(4, &[9], 5, 29, 0.0, 3);
        let rep = eng.run(reqs).unwrap();
        assert_eq!(rep.completions.len(), 4);
        assert_eq!(rep.stats.peak_active, 1, "budget should serialise sessions");
        assert_eq!(rep.stats.generated, 4 * 5);
    }

    #[test]
    fn synthetic_workload_cycles_the_mix() {
        let reqs = synthetic_workload(5, &[3, 7], 4, 29, 0.5, 11);
        assert_eq!(reqs.len(), 5);
        let lens: Vec<usize> = reqs.iter().map(|r| r.prompt.len()).collect();
        assert_eq!(lens, vec![3, 7, 3, 7, 3]);
        assert!(reqs.iter().all(|r| r.max_new == 4 && r.temperature == 0.5));
        assert!(reqs.iter().all(|r| r.prompt.iter().all(|&t| t < 29)));
        // distinct per-request seeds
        let mut seeds: Vec<u64> = reqs.iter().map(|r| r.seed).collect();
        seeds.sort_unstable();
        seeds.dedup();
        assert_eq!(seeds.len(), 5);
    }
}
