//! Model configuration for the CPU transformer stack — the rust mirror
//! of the L2 `ModelConfig` in `python/compile/model.py`, shared between
//! the CPU `htx infer` path and the coordinator's run-config files
//! (`coordinator::config::RunConfig::model_config`, xla tier).
//!
//! Key set (strict `key = value` files and `--key value` CLI flags use
//! the same names): `vocab_size`, `d_model`, `n_heads`, `n_layers`,
//! `d_ff`, `max_len`, `causal`, `attention` plus the per-algorithm
//! hyper-parameters `block_size` (h1d's Nr), `window`, `rank`,
//! `n_global`, `n_random`, `attn_seed`.

use crate::attention::{Attention, BlockSparse, Full, H1d, LocalWindow, LowRank};

/// Which zoo algorithm a model routes its per-layer attention through —
/// the drop-in point the paper describes (h1d replaces standard
/// multi-head attention without touching the rest of the stack).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum AttnSpec {
    Full,
    /// The paper's hierarchical attention; `nr` is the block size
    /// (the single model hyper-parameter, must be even and >= 2).
    H1d { nr: usize },
    Local { radius: usize },
    LowRank { rank: usize, seed: u64 },
    BlockSparse {
        window: usize,
        n_global: usize,
        n_random: usize,
        seed: u64,
    },
}

impl AttnSpec {
    pub fn name(&self) -> &'static str {
        match self {
            AttnSpec::Full => "full",
            AttnSpec::H1d { .. } => "h1d",
            AttnSpec::Local { .. } => "local",
            AttnSpec::LowRank { .. } => "lowrank",
            AttnSpec::BlockSparse { .. } => "blocksparse",
        }
    }

    /// Instantiate the zoo algorithm this spec names.
    pub fn build(&self) -> Box<dyn Attention + Send + Sync> {
        match *self {
            AttnSpec::Full => Box::new(Full),
            AttnSpec::H1d { nr } => Box::new(H1d::new(nr)),
            AttnSpec::Local { radius } => Box::new(LocalWindow::new(radius)),
            AttnSpec::LowRank { rank, seed } => Box::new(LowRank::new(rank, seed)),
            AttnSpec::BlockSparse {
                window,
                n_global,
                n_random,
                seed,
            } => Box::new(BlockSparse::new(window, n_global, n_random, seed)),
        }
    }
}

/// Hyper-parameters for one CPU model variant. Field names and defaults
/// mirror the L2 jax `ModelConfig` so config files drive both stacks.
#[derive(Clone, Debug, PartialEq)]
pub struct ModelConfig {
    pub vocab_size: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub n_layers: usize,
    pub d_ff: usize,
    pub max_len: usize,
    pub causal: bool,
    pub attention: AttnSpec,
    /// Store the weight matrices of every matmul (QKV/Wo/FFN/logits) in
    /// per-row-scaled int8 alongside the f32 originals and route the
    /// projections through the quantised kernels — bounded-drift, not
    /// exact (see `model::QuantMat`).
    pub quant_weights: bool,
}

impl Default for ModelConfig {
    fn default() -> Self {
        Self {
            vocab_size: 256,
            d_model: 128,
            n_heads: 4,
            n_layers: 2,
            d_ff: 512,
            max_len: 512,
            causal: false,
            attention: AttnSpec::H1d { nr: 16 },
            quant_weights: false,
        }
    }
}

impl ModelConfig {
    pub fn d_head(&self) -> usize {
        self.d_model / self.n_heads
    }

    /// Reject configs that cannot build a model (bad head split, odd
    /// Nr, degenerate sizes) with a message instead of a mid-forward
    /// panic.
    pub fn validate(&self) -> Result<(), String> {
        if self.vocab_size < 2 {
            return Err(format!("vocab_size must be >= 2 (got {})", self.vocab_size));
        }
        if self.n_heads == 0 || self.d_model == 0 || self.d_model % self.n_heads != 0 {
            return Err(format!(
                "d_model {} must be a positive multiple of n_heads {}",
                self.d_model, self.n_heads
            ));
        }
        if self.n_layers == 0 {
            return Err("n_layers must be >= 1".to_string());
        }
        if self.d_ff == 0 {
            return Err("d_ff must be >= 1".to_string());
        }
        if self.max_len == 0 {
            return Err("max_len must be >= 1".to_string());
        }
        if let AttnSpec::H1d { nr } = self.attention {
            if nr < 2 || nr % 2 != 0 {
                return Err(format!("block_size (Nr) must be an even value >= 2 (got {nr})"));
            }
        }
        if self.causal && matches!(self.attention, AttnSpec::LowRank { .. }) {
            // Linformer-style projection has no exact causal variant and
            // the zoo implementation ignores the flag — a "causal"
            // lowrank decoder would silently attend to the future.
            return Err("attention = lowrank cannot run causal (the projection \
                        has no causal form; the flag would be ignored)"
                .to_string());
        }
        Ok(())
    }

    /// Resolve a config from any `key -> value` source (CLI [`Args`]
    /// flags, `RunConfig` files, tests). Unknown attention names and
    /// unparsable values are errors; missing keys take the defaults.
    ///
    /// [`Args`]: crate::util::cli::Args
    pub fn from_lookup<'a, F>(mut get: F) -> Result<ModelConfig, String>
    where
        F: FnMut(&str) -> Option<&'a str>,
    {
        fn pu<'a>(
            get: &mut impl FnMut(&str) -> Option<&'a str>,
            key: &str,
            default: usize,
        ) -> Result<usize, String> {
            match get(key) {
                None => Ok(default),
                Some(v) => v
                    .parse()
                    .map_err(|_| format!("bad {key}: {v:?} (expected an integer)")),
            }
        }
        fn pb<'a>(
            get: &mut impl FnMut(&str) -> Option<&'a str>,
            key: &str,
            default: bool,
        ) -> Result<bool, String> {
            match get(key) {
                None => Ok(default),
                Some("true") | Some("1") | Some("yes") => Ok(true),
                Some("false") | Some("0") | Some("no") => Ok(false),
                Some(v) => Err(format!("bad {key}: {v:?} (expected true/false)")),
            }
        }
        let d = ModelConfig::default();
        let vocab_size = pu(&mut get, "vocab_size", d.vocab_size)?;
        let d_model = pu(&mut get, "d_model", d.d_model)?;
        let n_heads = pu(&mut get, "n_heads", d.n_heads)?;
        let n_layers = pu(&mut get, "n_layers", d.n_layers)?;
        let d_ff = pu(&mut get, "d_ff", d.d_ff)?;
        let max_len = pu(&mut get, "max_len", d.max_len)?;
        let causal = pb(&mut get, "causal", d.causal)?;
        let quant_weights = pb(&mut get, "quant_weights", d.quant_weights)?;
        let attention = match get("attention").unwrap_or("h1d") {
            "full" => AttnSpec::Full,
            "h1d" => AttnSpec::H1d {
                nr: pu(&mut get, "block_size", 16)?,
            },
            "local" => AttnSpec::Local {
                radius: pu(&mut get, "window", 16)?,
            },
            "lowrank" => AttnSpec::LowRank {
                rank: pu(&mut get, "rank", 32)?,
                seed: pu(&mut get, "attn_seed", 7)? as u64,
            },
            "blocksparse" => AttnSpec::BlockSparse {
                window: pu(&mut get, "window", 8)?,
                n_global: pu(&mut get, "n_global", 4)?,
                n_random: pu(&mut get, "n_random", 4)?,
                seed: pu(&mut get, "attn_seed", 7)? as u64,
            },
            other => {
                return Err(format!(
                    "unknown attention {other:?} (full|h1d|local|lowrank|blocksparse)"
                ))
            }
        };
        let cfg = ModelConfig {
            vocab_size,
            d_model,
            n_heads,
            n_layers,
            d_ff,
            max_len,
            causal,
            attention,
            quant_weights,
        };
        cfg.validate()?;
        Ok(cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::BTreeMap;

    fn lookup(pairs: &[(&str, &str)]) -> BTreeMap<String, String> {
        pairs
            .iter()
            .map(|(k, v)| (k.to_string(), v.to_string()))
            .collect()
    }

    #[test]
    fn defaults_mirror_the_l2_zoo() {
        let cfg = ModelConfig::from_lookup(|_| None).unwrap();
        assert_eq!(cfg, ModelConfig::default());
        assert_eq!(cfg.d_head(), 32);
        assert_eq!(cfg.attention, AttnSpec::H1d { nr: 16 });
    }

    #[test]
    fn full_key_set_parses() {
        let kv = lookup(&[
            ("vocab_size", "512"),
            ("d_model", "64"),
            ("n_heads", "8"),
            ("n_layers", "3"),
            ("d_ff", "128"),
            ("max_len", "1024"),
            ("causal", "true"),
            ("attention", "blocksparse"),
            ("window", "6"),
            ("n_global", "2"),
            ("n_random", "3"),
            ("attn_seed", "11"),
        ]);
        let cfg = ModelConfig::from_lookup(|k| kv.get(k).map(|s| s.as_str())).unwrap();
        assert_eq!(cfg.vocab_size, 512);
        assert_eq!(cfg.d_head(), 8);
        assert!(cfg.causal);
        assert_eq!(
            cfg.attention,
            AttnSpec::BlockSparse {
                window: 6,
                n_global: 2,
                n_random: 3,
                seed: 11
            }
        );
    }

    #[test]
    fn bad_configs_are_rejected_with_messages() {
        let odd_nr = lookup(&[("attention", "h1d"), ("block_size", "7")]);
        let err = ModelConfig::from_lookup(|k| odd_nr.get(k).map(|s| s.as_str())).unwrap_err();
        assert!(err.contains("even"), "{err}");

        let bad_heads = lookup(&[("d_model", "100"), ("n_heads", "3")]);
        let err = ModelConfig::from_lookup(|k| bad_heads.get(k).map(|s| s.as_str())).unwrap_err();
        assert!(err.contains("n_heads"), "{err}");

        let unknown = lookup(&[("attention", "linear")]);
        let err = ModelConfig::from_lookup(|k| unknown.get(k).map(|s| s.as_str())).unwrap_err();
        assert!(err.contains("unknown attention"), "{err}");

        let junk = lookup(&[("d_ff", "many")]);
        assert!(ModelConfig::from_lookup(|k| junk.get(k).map(|s| s.as_str())).is_err());

        // lowrank ignores the causal flag, so a causal lowrank decoder
        // must be rejected instead of silently attending to the future
        let causal_lowrank = lookup(&[("attention", "lowrank"), ("causal", "true")]);
        let err = ModelConfig::from_lookup(|k| causal_lowrank.get(k).map(|s| s.as_str()))
            .unwrap_err();
        assert!(err.contains("causal"), "{err}");
    }

    #[test]
    fn validate_rejects_unsplittable_heads() {
        // d_model % n_heads != 0 must be an Err, not a mid-forward panic
        let cfg = ModelConfig {
            d_model: 100,
            n_heads: 3,
            ..ModelConfig::default()
        };
        let err = cfg.validate().unwrap_err();
        assert!(err.contains("n_heads"), "{err}");
        assert!(crate::model::Model::new(cfg, 1).is_err());
    }

    #[test]
    fn validate_rejects_zero_layers() {
        let cfg = ModelConfig {
            n_layers: 0,
            ..ModelConfig::default()
        };
        let err = cfg.validate().unwrap_err();
        assert!(err.contains("n_layers"), "{err}");
        assert!(crate::model::Model::new(cfg, 1).is_err());
    }

    #[test]
    fn validate_rejects_degenerate_vocab() {
        for vocab_size in [0usize, 1] {
            let cfg = ModelConfig {
                vocab_size,
                ..ModelConfig::default()
            };
            let err = cfg.validate().unwrap_err();
            assert!(err.contains("vocab_size"), "vocab {vocab_size}: {err}");
            assert!(crate::model::Model::new(cfg, 1).is_err());
        }
    }

    #[test]
    fn every_spec_builds_its_algorithm() {
        for (name, spec) in [
            ("full", AttnSpec::Full),
            ("h1d", AttnSpec::H1d { nr: 4 }),
            ("local", AttnSpec::Local { radius: 3 }),
            ("lowrank", AttnSpec::LowRank { rank: 4, seed: 1 }),
            (
                "blocksparse",
                AttnSpec::BlockSparse {
                    window: 2,
                    n_global: 1,
                    n_random: 1,
                    seed: 1,
                },
            ),
        ] {
            assert_eq!(spec.name(), name);
            assert_eq!(spec.build().name(), name);
        }
    }
}
