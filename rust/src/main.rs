//! `htx` — the H-Transformer-1D coordinator CLI.
//!
//! CPU-only subcommands (always available):
//!   rankmap                   reproduce the paper's Eq. (11)-(13) example
//!   scaling [--heads H]       batched attention scaling table (§7)
//!   infer [--attention A]     end-to-end CPU transformer forward (no
//!                             artifacts): builds a `model` stack from
//!                             the shared config key set (vocab_size,
//!                             d_model, n_heads, n_layers, d_ff,
//!                             max_len, causal, attention, block_size,
//!                             window, rank, ...) and reports logits +
//!                             throughput
//!   generate [--attention A]  KV-cached autoregressive decoding: one
//!                             prefill over a prompt, then per-token
//!                             `DecodeSession::step` sampling (greedy
//!                             or --temperature T); reports per-token
//!                             latency — the serving-style path where
//!                             h1d's incremental cost stays ~flat while
//!                             full attention grows with context.
//!                             --spec-k N turns on draft-and-verify
//!                             speculative decoding: a cheap draft
//!                             sibling built from the target's own
//!                             weights (--spec-draft, e.g.
//!                             `local:8,layers:1`) proposes N tokens
//!                             per round and the target verifies them
//!                             in one batched pass — same tokens as
//!                             plain decoding (greedy: bitwise), fewer
//!                             target passes. --window N retires KV
//!                             pages behind an N-token streaming
//!                             horizon after every step (exact: h1d
//!                             keeps its coarse pyramid as the far
//!                             field; logits stay bitwise identical)
//!                             and reports pages retired / peak
//!                             resident
//!   serve-bench               continuous-batching throughput: a
//!                             closed-loop synthetic workload
//!                             (--requests, --prompt-mix, --gen; or
//!                             --shared-prompt N for one shared
//!                             N-token prompt; or --system-prompt N
//!                             for the multi-tenant regime — one
//!                             shared N-token system prompt plus a
//!                             distinct suffix per request) driven
//!                             through `model::serve`'s scheduler at
//!                             --max-batch / --max-tokens budgets and
//!                             compared against the sequential
//!                             one-session-at-a-time loop (aggregate
//!                             tokens/s, p50/p95 per-token latency,
//!                             speedup). KV memory is paged
//!                             (--page-len; radix-tree whole- and
//!                             partial-prefix sharing via
//!                             --prefix-cache); --prefill-chunk N
//!                             interleaves long prompt prefills with
//!                             decode rounds N tokens at a time;
//!                             --reserve restores the
//!                             contiguous-reservation baseline
//!                             admission. --kv-dtype {f32|f16|int8}
//!                             (i8 is accepted as an int8 alias)
//!                             stores KV pages compressed (budget
//!                             charges shrink proportionally) and
//!                             --quant-weights routes every matmul
//!                             through int8 per-row quantised weights;
//!                             --spec-k / --spec-draft run every decode
//!                             round speculatively (acceptance rate and
//!                             effective tokens/step are reported);
//!                             --window N retires each session's KV
//!                             pages behind an N-token streaming
//!                             horizon after every round (output-exact;
//!                             peak per-session residency and retired
//!                             pages are reported)
//!   serve --listen ADDR       HTTP/1.1 serving front end over the
//!                             continuous-batching engine: POST
//!                             /generate with token-id prompts streams
//!                             chunked NDJSON tokens; GET /metrics
//!                             reports latency percentiles, queue
//!                             depth, pages-in-use and prefix-hit
//!                             rate. Requests shard across --workers
//!                             engine workers (per-worker page pools,
//!                             least-loaded routing with a
//!                             consistent-hash tiebreak on the prompt
//!                             prefix). Engine knobs match serve-bench
//!                             (--max-batch, --max-tokens, --page-len,
//!                             --prefix-cache, --prefill-chunk,
//!                             --reserve, --kv-dtype, --quant-weights,
//!                             --worker-threads, --window, --spec-k /
//!                             --spec-draft);
//!                             front-end knobs: --max-queue (503
//!                             backpressure cap), --read-timeout-ms /
//!                             --write-timeout-ms (per-connection
//!                             socket timeouts), --metrics-jsonl PATH
//!                             (per-request JSONL records). SIGINT
//!                             drains in-flight sessions, then prints
//!                             the final /metrics snapshot
//!
//! Artifact-backed subcommands (need `--features xla` + `make artifacts`):
//!   list                      show the model zoo from the manifest
//!   train   --model NAME      train a model on its synthetic task
//!   eval    --model NAME      evaluate (fresh init or --checkpoint)
//!   serve   --model NAME      demo the batching inference server
//!                             (without --listen; the HTTP front end
//!                             above takes precedence when --listen is
//!                             given)
//!
//! All heavy math runs in AOT-compiled XLA artifacts; python is never on
//! this binary's path. The CPU subcommands run the crate's own batched
//! attention mirror through its workspace-reuse API.

use std::time::Duration;

use htransformer::attention::{
    Attention, AttnWorkspace, BlockSparse, Full, H1d, LocalWindow, LowRank,
};
use htransformer::hmatrix::toeplitz;
use htransformer::model::{
    sample_logits, DecodeWorkspace, Model, ModelConfig, ModelWorkspace, SpecDraft,
};
use htransformer::tensor::{Batch, PageDtype, Qkv};
use htransformer::util::bench::{bench_for, fmt_time, Table};
use htransformer::util::cli::Args;
use htransformer::util::Rng;

fn main() {
    let args = Args::from_env();
    let result: Result<(), String> = match args.subcommand.as_deref() {
        Some("rankmap") => {
            cmd_rankmap();
            Ok(())
        }
        Some("scaling") => {
            cmd_scaling(&args);
            Ok(())
        }
        Some("infer") => cmd_infer(&args),
        Some("generate") => cmd_generate(&args),
        Some("serve-bench") => cmd_serve_bench(&args),
        // `serve --listen` is the CPU HTTP front end; without --listen
        // the name falls through to the xla artifact demo server
        Some("serve") if args.get("listen").is_some() => cmd_serve_net(&args),
        #[cfg(feature = "xla")]
        Some("list") => xla_cmds::cmd_list(&args).map_err(|e| format!("{e:#}")),
        #[cfg(feature = "xla")]
        Some("train") => xla_cmds::cmd_train(&args).map_err(|e| format!("{e:#}")),
        #[cfg(feature = "xla")]
        Some("eval") => xla_cmds::cmd_eval(&args).map_err(|e| format!("{e:#}")),
        #[cfg(feature = "xla")]
        Some("serve") => xla_cmds::cmd_serve(&args).map_err(|e| format!("{e:#}")),
        #[cfg(not(feature = "xla"))]
        Some("serve") => Err(
            "serve needs --listen <addr> for the HTTP front end \
             (the artifact demo server needs --features xla)"
                .to_string(),
        ),
        other => {
            eprintln!(
                "usage: htx <rankmap|scaling|infer|generate|serve-bench|serve \
                 --listen|list|train|eval> [flags]\n\
                 (got {other:?}; list/train/eval and serve-without---listen need \
                 --features xla; see DESIGN.md)"
            );
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn cmd_rankmap() {
    let demo = toeplitz::run_demo();
    println!("Eq. (11)-(13) reproduction (16x16 Toeplitz attention matrix)");
    println!(
        "global numerical rank: {} @ eps=1e-3, {} @ eps=1e-1 (paper: 16, 16)",
        demo.global_rank_tight, demo.global_rank_loose
    );
    println!("block rank map (paper Eq. 13: diag=4, off-diag=2):");
    for b in &demo.blocks {
        println!(
            "  level {} block ({:>2},{:>2}) size {:>2}: rank {}",
            b.level,
            b.r0 / b.size,
            b.c0 / b.size,
            b.size,
            b.rank
        );
    }
    println!(
        "storage: hierarchical {} vs dense {} entries (compression {:.3}; paper: 192 vs 256 = 4/3)",
        demo.hier_storage,
        demo.dense_storage,
        demo.dense_storage as f64 / demo.hier_storage as f64
    );
}

fn cmd_scaling(args: &Args) {
    let d = args.usize_or("d", 32);
    let heads = args.usize_or("heads", 1);
    let budget = Duration::from_millis(args.u64_or("budget-ms", 300));
    let lens = [128usize, 256, 512, 1024, 2048, 4096];
    let algos: Vec<Box<dyn Attention>> = vec![
        Box::new(Full),
        Box::new(LocalWindow::new(16)),
        Box::new(LowRank::new(32, 7)),
        Box::new(BlockSparse::new(8, 4, 4, 7)),
        Box::new(H1d::new(16)),
    ];
    let mut ws = if heads > 1 {
        AttnWorkspace::parallel()
    } else {
        AttnWorkspace::serial()
    };
    println!(
        "batched attention scaling (B=1, H={heads}, d={d}, {} worker thread(s))",
        ws.threads()
    );
    let mut t = Table::new(&[
        "L", "full", "local", "lowrank", "blocksparse", "h1d", "h1d mem", "full mem",
    ]);
    for &l in &lens {
        let mut rng = Rng::new(l as u64);
        let qkv = Qkv::new(
            Batch::random(1, heads, l, d, &mut rng),
            Batch::random(1, heads, l, d, &mut rng),
            Batch::random(1, heads, l, d, &mut rng),
        );
        let mut cells = vec![l.to_string()];
        for algo in &algos {
            let meas = bench_for(algo.name(), 1, budget, || {
                std::hint::black_box(algo.forward_batch(&mut ws, &qkv, false));
            });
            cells.push(fmt_time(meas.min_s));
        }
        cells.push(format!("{}KB", heads * algos[4].attn_memory_bytes(l, d) / 1024));
        cells.push(format!("{}KB", heads * algos[0].attn_memory_bytes(l, d) / 1024));
        t.row(&cells);
    }
    t.print();
    println!("\nh1d should scale ~linearly in L; full ~quadratically (paper §7).");
}

fn cmd_infer(args: &Args) -> Result<(), String> {
    let cfg = ModelConfig::from_lookup(|k| args.get(k))?;
    let seed = args.u64_or("seed", 42);
    let batch = args.usize_or("batch", 2);
    let len = args.usize_or("len", cfg.max_len.min(128));
    let threads = args.usize_or("threads", 0); // 0 = host parallelism
    let repeats = args.usize_or("repeats", 3);
    if batch == 0 {
        return Err("--batch must be >= 1".to_string());
    }
    if len == 0 || len > cfg.max_len {
        return Err(format!(
            "--len {len} outside 1..={} (raise --max_len to go longer)",
            cfg.max_len
        ));
    }
    let model = Model::new(cfg, seed)?;
    let cfg = &model.cfg;
    let mut ws = if threads == 0 {
        ModelWorkspace::parallel()
    } else {
        ModelWorkspace::new(threads)
    };
    println!(
        "model: {} layers x {} heads, d_model {}, d_ff {}, vocab {}, attention {}{} ({} params)",
        cfg.n_layers,
        cfg.n_heads,
        cfg.d_model,
        cfg.d_ff,
        cfg.vocab_size,
        model.attention_name(),
        if cfg.causal { " (causal)" } else { "" },
        model.n_params()
    );
    let mut rng = Rng::new(seed ^ 0x5EED);
    let tokens: Vec<u32> = (0..batch * len)
        .map(|_| rng.below(cfg.vocab_size as u64) as u32)
        .collect();

    let t0 = std::time::Instant::now();
    let logits = model.forward(&mut ws, &tokens, batch);
    let cold = t0.elapsed().as_secs_f64();
    println!(
        "forward: [{batch}, {len}] tokens -> [{}, {}] logits in {} (cold)",
        logits.rows,
        logits.cols,
        fmt_time(cold)
    );
    for bi in 0..batch {
        let last = logits.row((bi + 1) * len - 1);
        let (mut arg, mut best) = (0usize, f32::NEG_INFINITY);
        for (j, &v) in last.iter().enumerate() {
            if v > best {
                best = v;
                arg = j;
            }
        }
        println!("  seq {bi}: next-token argmax {arg} (logit {best:.4})");
    }
    // warm steady state: repeated same-shape calls reuse every buffer
    let mut warm = f64::INFINITY;
    for _ in 0..repeats.max(1) {
        let t1 = std::time::Instant::now();
        std::hint::black_box(model.forward(&mut ws, &tokens, batch));
        warm = warm.min(t1.elapsed().as_secs_f64());
    }
    println!(
        "warm: {} / forward ({:.0} tokens/s, zero workspace allocations)",
        fmt_time(warm),
        (batch * len) as f64 / warm
    );
    Ok(())
}

fn cmd_generate(args: &Args) -> Result<(), String> {
    // decoding wants a causal model; default the flag on unless the user
    // set it or picked lowrank (which has no causal form and decodes in
    // encoder mode, each step attending the whole prefix)
    let default_causal = args.get("attention").unwrap_or("h1d") != "lowrank";
    let cfg = ModelConfig::from_lookup(|k| {
        args.get(k).or_else(|| match (k, default_causal) {
            ("causal", true) => Some("true"),
            _ => None,
        })
    })?;
    let seed = args.u64_or("seed", 42);
    let prompt_len = args.usize_or("prompt-len", 8);
    let n_gen = args.usize_or("gen", 32);
    let temperature = args.f64_or("temperature", 0.0) as f32;
    let threads = args.usize_or("threads", 0); // 0 = host parallelism
    let spec_k = args.usize_or("spec-k", 0); // 0 = plain decoding
    let window = args.usize_or("window", 0); // 0 = keep the whole history
    if args.get("spec-draft").is_some() && spec_k == 0 {
        return Err("--spec-draft needs --spec-k >= 1 to turn speculation on".to_string());
    }
    if window > 0 && spec_k > 0 {
        return Err(
            "--window cannot combine with --spec-k: speculative rollback replays fine \
             history the window may already have retired"
                .to_string(),
        );
    }
    if prompt_len == 0 {
        return Err("--prompt-len must be >= 1".to_string());
    }
    if prompt_len + n_gen > cfg.max_len {
        return Err(format!(
            "--prompt-len {prompt_len} + --gen {n_gen} exceeds max_len {} \
             (raise --max_len to go longer)",
            cfg.max_len
        ));
    }
    let model = Model::new(cfg, seed)?;
    let cfg = &model.cfg;
    println!(
        "model: {} layers x {} heads, d_model {}, vocab {}, attention {}{} ({} params)",
        cfg.n_layers,
        cfg.n_heads,
        cfg.d_model,
        cfg.vocab_size,
        model.attention_name(),
        if cfg.causal { " (causal)" } else { "" },
        model.n_params()
    );
    let mut rng = Rng::new(seed ^ 0xDEC0DE);
    let prompt: Vec<u32> = (0..prompt_len)
        .map(|_| rng.below(cfg.vocab_size as u64) as u32)
        .collect();

    if spec_k > 0 {
        if !cfg.causal {
            return Err(
                "--spec-k needs a causal model (draft-and-verify replays strictly \
                 left-to-right decode steps)"
                    .to_string(),
            );
        }
        let spec = SpecDraft::parse(&args.str_or("spec-draft", "local:8,layers:1"))?;
        let draft = spec.build(&model)?;
        println!(
            "draft: {} — {} layer(s), {} params, proposing up to {spec_k} token(s)/round",
            spec.label(),
            draft.cfg.n_layers,
            draft.n_params()
        );
        let t0 = std::time::Instant::now();
        let (out_tokens, totals) = htransformer::model::spec::generate(
            &model,
            &draft,
            spec_k,
            &prompt,
            n_gen,
            temperature,
            &mut rng,
        )?;
        let wall = t0.elapsed().as_secs_f64();
        println!(
            "sampled {} tokens ({}, seed {seed}):",
            out_tokens.len(),
            if temperature > 0.0 {
                format!("temperature {temperature}")
            } else {
                "greedy".to_string()
            }
        );
        let rendered: Vec<String> = out_tokens.iter().map(|t| t.to_string()).collect();
        println!("  {}", rendered.join(" "));
        println!(
            "speculation: {} target round(s), {}/{} proposals accepted ({:.0}%), \
             {:.2} tokens/round; prefill+decode {} ({:.0} tokens/s)",
            totals.rounds,
            totals.accepted,
            totals.proposed,
            100.0 * totals.acceptance_rate(),
            totals.tokens_per_round(),
            fmt_time(wall),
            out_tokens.len() as f64 / wall.max(1e-9)
        );
        return Ok(());
    }

    let ws = if threads == 0 {
        DecodeWorkspace::parallel()
    } else {
        DecodeWorkspace::new(threads)
    };
    let t0 = std::time::Instant::now();
    let mut session = model.prefill_with(ws, &prompt)?;
    let prefill_t = t0.elapsed().as_secs_f64();
    println!(
        "prefill: {prompt_len} prompt tokens in {} ({:.0} tokens/s)",
        fmt_time(prefill_t),
        prompt_len as f64 / prefill_t
    );

    let mut out_tokens = Vec::with_capacity(n_gen);
    let mut next = sample_logits(session.logits().row(0), temperature, &mut rng) as u32;
    let mut step_total = 0.0f64;
    let mut step_min = f64::INFINITY;
    let mut retired_pages = 0usize;
    let mut peak_resident = 0usize;
    for _ in 0..n_gen {
        out_tokens.push(next);
        let t1 = std::time::Instant::now();
        let logits = session.step(next)?;
        let dt = t1.elapsed().as_secs_f64();
        step_total += dt;
        step_min = step_min.min(dt);
        next = sample_logits(logits.row(0), temperature, &mut rng) as u32;
        if window > 0 {
            retired_pages += session.retire_window(window);
            peak_resident = peak_resident.max(session.resident_pages());
        }
    }
    println!(
        "sampled {n_gen} tokens ({}, seed {seed}):",
        if temperature > 0.0 {
            format!("temperature {temperature}")
        } else {
            "greedy".to_string()
        }
    );
    let rendered: Vec<String> = out_tokens.iter().map(|t| t.to_string()).collect();
    println!("  {}", rendered.join(" "));
    if n_gen > 0 {
        println!(
            "decode: {} / token mean, {} min ({:.0} tokens/s; context {} -> {})",
            fmt_time(step_total / n_gen as f64),
            fmt_time(step_min),
            n_gen as f64 / step_total,
            prompt_len,
            session.pos()
        );
    }
    if window > 0 {
        println!(
            "streaming window {window}: {retired_pages} page(s) retired, peak {} resident \
             page(s) (now {})",
            peak_resident.max(session.resident_pages()),
            session.resident_pages()
        );
    }
    Ok(())
}

fn cmd_serve_bench(args: &Args) -> Result<(), String> {
    use htransformer::model::{run_sequential_dtype, synthetic_workload, ServeConfig, ServeEngine};
    use std::sync::Arc;

    // decoding wants a causal model, same defaulting rule as `generate`
    let default_causal = args.get("attention").unwrap_or("h1d") != "lowrank";
    let mut cfg = ModelConfig::from_lookup(|k| {
        args.get(k).or_else(|| match (k, default_causal) {
            ("causal", true) => Some("true"),
            _ => None,
        })
    })?;
    // hyphenated CLI alias for the config key
    if args.bool("quant-weights") {
        cfg.quant_weights = true;
    }
    let kv_flag = args.str_or("kv-dtype", "f32");
    let kv_dtype = PageDtype::parse(&kv_flag)
        .ok_or_else(|| format!("--kv-dtype expects f32|f16|int8, got {kv_flag:?}"))?;
    let seed = args.u64_or("seed", 42);
    let n_requests = args.usize_or("requests", 16);
    let max_batch = args.usize_or("max-batch", 8);
    let max_tokens = args.usize_or("max-tokens", 0); // 0 = unlimited
    let gen = args.usize_or("gen", 16);
    let temperature = args.f64_or("temperature", 0.0) as f32;
    let threads = args.usize_or("threads", 0); // 0 = host parallelism
    let page_len = args.usize_or("page-len", 16);
    let reserve = args.bool("reserve"); // contiguous-reservation baseline
    let prefix_cache = args.usize_or("prefix-cache", 8);
    let prefill_chunk = args.usize_or("prefill-chunk", 0); // 0 = whole-prompt prefill
    let window = args.usize_or("window", 0); // 0 = keep whole histories
    let spec_k = args.usize_or("spec-k", 0); // 0 = plain decode rounds
    if args.get("spec-draft").is_some() && spec_k == 0 {
        return Err("--spec-draft needs --spec-k >= 1 to turn speculation on".to_string());
    }
    let spec_draft = if spec_k > 0 {
        Some(SpecDraft::parse(&args.str_or("spec-draft", "local:8,layers:1"))?)
    } else {
        None
    };
    let shared_prompt = args.usize_or("shared-prompt", 0); // 0 = mixed prompts
    let system_prompt = args.usize_or("system-prompt", 0); // 0 = no shared system prefix
    let mix: Vec<usize> = args
        .str_or("prompt-mix", "16,32,48")
        .split(',')
        .map(|s| {
            s.trim()
                .parse::<usize>()
                .map_err(|_| format!("--prompt-mix expects comma-separated lengths, got {s:?}"))
        })
        .collect::<Result<_, _>>()?;
    if n_requests == 0 || gen == 0 || mix.is_empty() {
        return Err("--requests, --gen and --prompt-mix must be non-empty".to_string());
    }
    let longest = mix.iter().copied().max().unwrap_or(0);
    if longest + gen > cfg.max_len {
        return Err(format!(
            "prompt {longest} + gen {gen} exceeds max_len {} (raise --max_len)",
            cfg.max_len
        ));
    }
    if shared_prompt > 0 && shared_prompt + gen > cfg.max_len {
        return Err(format!(
            "--shared-prompt {shared_prompt} + gen {gen} exceeds max_len {} (raise --max_len)",
            cfg.max_len
        ));
    }
    if shared_prompt > 0 && system_prompt > 0 {
        return Err("--shared-prompt and --system-prompt are mutually exclusive".to_string());
    }
    // --system-prompt N: multi-tenant regime, suffix lengths from the
    // first --prompt-mix entry
    if system_prompt > 0 && system_prompt + mix[0] + gen > cfg.max_len {
        return Err(format!(
            "--system-prompt {system_prompt} + suffix {} + gen {gen} exceeds max_len {} \
             (raise --max_len)",
            mix[0], cfg.max_len
        ));
    }
    let model = Arc::new(Model::new(cfg, seed)?);
    let cfg = &model.cfg;
    println!(
        "model: {} layers x {} heads, d_model {}, vocab {}, attention {}{} ({} params)",
        cfg.n_layers,
        cfg.n_heads,
        cfg.d_model,
        cfg.vocab_size,
        model.attention_name(),
        if cfg.causal { " (causal)" } else { "" },
        model.n_params()
    );
    let requests = if shared_prompt > 0 {
        htransformer::model::shared_prefix_workload(
            n_requests,
            shared_prompt,
            gen,
            cfg.vocab_size,
            temperature,
            seed ^ 0x5EB,
        )
    } else if system_prompt > 0 {
        htransformer::model::multi_tenant_workload(
            n_requests,
            system_prompt,
            mix[0],
            gen,
            cfg.vocab_size,
            temperature,
            seed ^ 0x5EB,
        )
    } else {
        synthetic_workload(n_requests, &mix, gen, cfg.vocab_size, temperature, seed ^ 0x5EB)
    };
    if shared_prompt > 0 {
        println!(
            "workload: {n_requests} requests sharing one {shared_prompt}-token prompt, \
             {gen} tokens each ({} total to generate)\n",
            n_requests * gen
        );
    } else if system_prompt > 0 {
        println!(
            "workload: {n_requests} requests sharing one {system_prompt}-token system \
             prompt + {}-token distinct suffixes, {gen} tokens each ({} total to \
             generate)\n",
            mix[0],
            n_requests * gen
        );
    } else {
        println!(
            "workload: {n_requests} requests, prompt mix {mix:?}, {gen} tokens each \
             ({} total to generate)\n",
            n_requests * gen
        );
    }

    // same-dtype sequential loop: the parity guard below pins the
    // scheduler, not the (bounded-drift) compression
    let seq = run_sequential_dtype(&model, &requests, kv_dtype)?;
    let workers = if threads == 0 {
        htransformer::util::threadpool::default_threads()
    } else {
        threads
    };
    let scfg = ServeConfig {
        max_batch,
        max_tokens: if max_tokens == 0 { usize::MAX } else { max_tokens },
        page_len,
        reserve,
        prefix_cache,
        prefill_chunk,
        threads: workers,
        kv_dtype,
        window,
        spec_draft: spec_draft.clone(),
        spec_k,
    };
    let mut engine = ServeEngine::new(Arc::clone(&model), scfg)?;
    let batched = engine.run(requests)?;
    // scheduling must never change results — guard the comparison
    if seq.tokens_by_id() != batched.tokens_by_id() {
        return Err("batched and sequential runs diverged (parity bug)".to_string());
    }

    let mut t = Table::new(&[
        "mode", "tokens/s", "per-token", "p50", "p95", "wall", "occupancy",
    ]);
    for (mode, rep) in [("sequential", &seq), ("continuous", &batched)] {
        t.row(&[
            mode.to_string(),
            format!("{:.0}", rep.stats.tokens_per_sec()),
            format!("{:.1}µs", rep.stats.per_token_us()),
            format!("{:.1}µs", rep.stats.latency_us(50.0)),
            format!("{:.1}µs", rep.stats.latency_us(95.0)),
            fmt_time(rep.stats.wall_s),
            format!("{:.2}", rep.stats.mean_occupancy()),
        ]);
    }
    t.print();
    println!(
        "\ncontinuous batching: {:.2}x aggregate throughput vs one-session-at-a-time \
         (max_batch {max_batch}, {workers} worker thread(s), peak active {})",
        batched.stats.tokens_per_sec() / seq.stats.tokens_per_sec().max(1e-9),
        batched.stats.peak_active
    );
    println!(
        "paged KV ({}, {} pages, {} weights): page_len {page_len}, peak {} pages / {} ctx \
         tokens, prefix-cache hit rate {:.0}% ({}/{} admissions), {} eviction(s)",
        if reserve { "reserved baseline" } else { "demand-grown" },
        kv_dtype.as_str(),
        if model.cfg.quant_weights { "int8" } else { "f32" },
        batched.stats.peak_pages,
        batched.stats.peak_ctx_tokens,
        100.0 * batched.stats.prefix_hit_rate(),
        batched.stats.prefix_hits,
        batched.stats.prefix_lookups,
        batched.stats.evictions
    );
    println!(
        "session residency: peak {} page(s) in any one session{}",
        batched.stats.peak_session_pages,
        if window > 0 {
            format!(
                ", streaming window {window}: {} page(s) retired to the pool",
                batched.stats.window_retired_pages
            )
        } else {
            String::new()
        }
    );
    let total_prompt = batched.stats.prefill_tokens + batched.stats.prefill_tokens_saved;
    println!(
        "radix prefix sharing: {} of {} prompt tokens prefilled, {} saved ({:.0}% of the \
         prompt work)",
        batched.stats.prefill_tokens,
        total_prompt,
        batched.stats.prefill_tokens_saved,
        100.0 * batched.stats.prefill_tokens_saved as f64 / total_prompt.max(1) as f64
    );
    if let Some(spec) = &spec_draft {
        println!(
            "speculative decoding (draft {}, k={spec_k}): {} round(s), {}/{} proposals \
             accepted ({:.0}%), {:.2} effective tokens/step",
            spec.label(),
            batched.stats.spec_rounds,
            batched.stats.draft_accepted,
            batched.stats.draft_proposed,
            100.0 * batched.stats.spec_acceptance_rate(),
            batched.stats.spec_tokens_per_step()
        );
    }
    if let (Some(p50), Some(p99)) = (
        batched.stats.try_tick_latency_us(50.0),
        batched.stats.try_tick_latency_us(99.0),
    ) {
        println!(
            "inter-token tick latency (prefill chunks included{}): p50 {:.1}µs, p99 {:.1}µs",
            if prefill_chunk > 0 {
                format!(", --prefill-chunk {prefill_chunk}")
            } else {
                String::new()
            },
            p50,
            p99
        );
    }
    Ok(())
}

/// SIGINT flag for the serving front end, set from the signal handler.
static SIGINT: std::sync::atomic::AtomicBool = std::sync::atomic::AtomicBool::new(false);

#[cfg(unix)]
fn install_sigint() {
    // No libc crate in the vendored set — bind the libc symbol directly.
    extern "C" {
        fn signal(signum: i32, handler: usize) -> usize;
    }
    extern "C" fn on_sigint(_signum: i32) {
        SIGINT.store(true, std::sync::atomic::Ordering::SeqCst);
    }
    const SIGINT_NUM: i32 = 2;
    unsafe {
        signal(SIGINT_NUM, on_sigint as usize);
    }
}

#[cfg(not(unix))]
fn install_sigint() {
    // No portable std signal API; ctrl-c falls back to hard exit here.
}

fn cmd_serve_net(args: &Args) -> Result<(), String> {
    use htransformer::model::{NetConfig, NetServer, ServeConfig};
    use std::sync::atomic::Ordering;
    use std::sync::Arc;

    let listen = args.get("listen").ok_or("serve needs --listen <addr>")?.to_string();
    // decoding wants a causal model, same defaulting rule as `generate`
    let default_causal = args.get("attention").unwrap_or("h1d") != "lowrank";
    let mut cfg = ModelConfig::from_lookup(|k| {
        args.get(k).or_else(|| match (k, default_causal) {
            ("causal", true) => Some("true"),
            _ => None,
        })
    })?;
    // hyphenated CLI alias for the config key
    if args.bool("quant-weights") {
        cfg.quant_weights = true;
    }
    let kv_flag = args.str_or("kv-dtype", "f32");
    let kv_dtype = PageDtype::parse(&kv_flag)
        .ok_or_else(|| format!("--kv-dtype expects f32|f16|int8 (alias i8), got {kv_flag:?}"))?;
    let seed = args.u64_or("seed", 42);
    let workers = args.usize_or("workers", 2);
    let worker_threads = args.usize_or("worker-threads", 1);
    let max_batch = args.usize_or("max-batch", 8);
    let max_tokens = args.usize_or("max-tokens", 0); // 0 = unlimited
    let page_len = args.usize_or("page-len", 16);
    let reserve = args.bool("reserve");
    let prefix_cache = args.usize_or("prefix-cache", 8);
    let prefill_chunk = args.usize_or("prefill-chunk", 0);
    let window = args.usize_or("window", 0); // 0 = keep whole histories
    let spec_k = args.usize_or("spec-k", 0); // 0 = plain decode rounds
    if args.get("spec-draft").is_some() && spec_k == 0 {
        return Err("--spec-draft needs --spec-k >= 1 to turn speculation on".to_string());
    }
    let spec_draft = if spec_k > 0 {
        Some(SpecDraft::parse(&args.str_or("spec-draft", "local:8,layers:1"))?)
    } else {
        None
    };
    let max_queue = args.usize_or("max-queue", 64);
    let read_timeout_ms = args.u64_or("read-timeout-ms", 10_000);
    let write_timeout_ms = args.u64_or("write-timeout-ms", 10_000);
    let metrics_jsonl = args.get("metrics-jsonl").map(std::path::PathBuf::from);
    if workers == 0 {
        return Err("--workers must be at least 1".to_string());
    }

    let model = Arc::new(Model::new(cfg, seed)?);
    let cfg = &model.cfg;
    println!(
        "model: {} layers x {} heads, d_model {}, vocab {}, attention {}{} ({} params)",
        cfg.n_layers,
        cfg.n_heads,
        cfg.d_model,
        cfg.vocab_size,
        model.attention_name(),
        if cfg.causal { " (causal)" } else { "" },
        model.n_params()
    );
    let net_cfg = NetConfig {
        workers,
        max_queue,
        read_timeout: Duration::from_millis(read_timeout_ms),
        write_timeout: Duration::from_millis(write_timeout_ms),
        metrics_jsonl,
        serve: ServeConfig {
            max_batch,
            max_tokens: if max_tokens == 0 { usize::MAX } else { max_tokens },
            page_len,
            reserve,
            prefix_cache,
            prefill_chunk,
            threads: worker_threads,
            kv_dtype,
            window,
            spec_draft: spec_draft.clone(),
            spec_k,
        },
        ..NetConfig::default()
    };
    let server = NetServer::start(model, &listen, net_cfg)?;
    // the e2e harness greps this exact line to discover the bound port
    println!("listening on {}", server.local_addr());
    println!(
        "{workers} worker(s) x {worker_threads} thread(s), max_batch {max_batch}, \
         page_len {page_len}, kv {}, queue cap {max_queue} (503 past that){}; ctrl-c drains",
        kv_dtype.as_str(),
        match &spec_draft {
            Some(spec) => format!(", speculative (draft {}, k={spec_k})", spec.label()),
            None => String::new(),
        }
    );
    install_sigint();
    while !SIGINT.load(Ordering::SeqCst) && !server.shutdown_flag().load(Ordering::SeqCst) {
        std::thread::sleep(Duration::from_millis(50));
    }
    eprintln!("shutting down: draining in-flight sessions");
    let final_metrics = server.shutdown();
    println!("{}", final_metrics.to_string());
    Ok(())
}

#[cfg(feature = "xla")]
mod xla_cmds {
    use std::time::Duration;

    use anyhow::{bail, Context, Result};

    use htransformer::coordinator::{self, spawn_source_for, Trainer};
    use htransformer::runtime::{default_artifacts_dir, Manifest};
    use htransformer::util::bench::Table;
    use htransformer::util::cli::Args;
    use htransformer::util::Rng;

    fn manifest(args: &Args) -> Result<Manifest> {
        let dir = args
            .get("artifacts")
            .map(std::path::PathBuf::from)
            .unwrap_or_else(default_artifacts_dir);
        Manifest::load(dir)
    }

    pub fn cmd_list(args: &Args) -> Result<()> {
        let m = manifest(args)?;
        let mut t = Table::new(&["model", "task", "attention", "Nr", "params", "L", "batch"]);
        for (name, e) in &m.models {
            t.row(&[
                name.clone(),
                e.task.clone(),
                e.config.attention.clone(),
                e.config.block_size.to_string(),
                format!("{}", e.param_count),
                e.config.max_len.to_string(),
                e.batch.to_string(),
            ]);
        }
        t.print();
        println!("\nattention microbench artifacts: {}", m.attention.len());
        Ok(())
    }

    pub fn cmd_train(args: &Args) -> Result<()> {
        let m = manifest(args)?;
        // config file (if any) provides defaults; CLI flags override
        let cfg = match args.get("config") {
            Some(path) => coordinator::RunConfig::load(path)?,
            None => coordinator::RunConfig::default(),
        };
        let (model, opts) = cfg.train_options(args)?;
        let model = model.as_str();
        let mut trainer = Trainer::new(&m, model, opts.seed as i32)?;
        println!(
            "training {model} ({} params, attention={}, Nr={}) for {} steps",
            trainer.n_params(),
            trainer.model.config.attention,
            trainer.model.config.block_size,
            opts.steps
        );
        let train_src = spawn_source_for(&trainer.model, opts.seed, 4);
        let eval_src = spawn_source_for(&trainer.model, opts.seed ^ 0xE7A1, 2);
        let report = trainer.run(&train_src, Some(&eval_src), &opts)?;
        println!(
            "done: final loss {:.4}, {:.2} steps/s ({:.1}s wall)",
            report.final_loss, report.steps_per_sec, report.wall_secs
        );
        Ok(())
    }

    pub fn cmd_eval(args: &Args) -> Result<()> {
        let m = manifest(args)?;
        let model = args.get("model").context("--model required")?;
        let mut trainer = Trainer::new(&m, model, args.u64_or("seed", 42) as i32)?;
        if let Some(ck) = args.get("checkpoint") {
            trainer.load_checkpoint(std::path::Path::new(ck))?;
            println!("loaded checkpoint at step {}", trainer.step);
        }
        let src = spawn_source_for(&trainer.model, args.u64_or("seed", 7), 2);
        let ev = trainer.evaluate(&src, args.usize_or("batches", 8))?;
        if trainer.model.task == "lm" {
            println!("eval: nll {:.4}, perplexity {:.3}", ev.mean_nll, ev.perplexity());
        } else {
            println!("eval: loss {:.4}, accuracy {:.3}", ev.mean_nll, ev.accuracy);
        }
        Ok(())
    }

    pub fn cmd_serve(args: &Args) -> Result<()> {
        let model = args.get("model").context("--model required")?.to_string();
        let dir = args
            .get("artifacts")
            .map(std::path::PathBuf::from)
            .unwrap_or_else(default_artifacts_dir);
        let n_requests = args.usize_or("requests", 64);
        let opts = coordinator::server::ServeOptions {
            max_wait: Duration::from_millis(args.u64_or("max-wait-ms", 5)),
            seed: args.u64_or("seed", 42) as i32,
            checkpoint: args.get("checkpoint").map(std::path::PathBuf::from),
        };
        let handle = coordinator::server::start(dir, model.clone(), opts)?;
        if !handle.wait_ready(Duration::from_secs(120)) {
            bail!("server failed to become ready");
        }
        println!("serving {model}; firing {n_requests} requests...");
        let seq = handle.seq_len;
        let mut rng = Rng::new(1);
        let mut receivers = Vec::new();
        for _ in 0..n_requests {
            let toks: Vec<i32> = (0..seq).map(|_| 2 + rng.below(100) as i32).collect();
            receivers.push(handle.submit(toks));
        }
        for rx in receivers {
            rx.recv().context("response")?.map_err(anyhow::Error::msg)?;
        }
        let s = handle.stats();
        println!(
            "served {} requests in {} batches (fill {:.2}); p50 {:.1}ms p99 {:.1}ms exec {:.1}ms",
            s.served,
            s.batches,
            s.mean_batch_fill,
            s.p50_latency * 1e3,
            s.p99_latency * 1e3,
            s.exec_mean * 1e3
        );
        handle.shutdown();
        Ok(())
    }
}
