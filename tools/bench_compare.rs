//! CI perf-regression gate over the `BENCH_*.json` trajectory.
//!
//! Usage:
//!   bench_compare --baseline BENCH_baseline.json \
//!       [--threshold 1.5] [--write-merged PATH] CURRENT.json...
//!
//! Every input follows the stable trajectory schema
//! `{commit, config, points[]}` where each point has a unique `id` and
//! a `per_token_us` metric (lower is better). The gate matches points
//! by `id` and computes per-point ratios `current / baseline`.
//!
//! **Machine-speed normalisation:** CI runners and the machines that
//! commit baselines differ in absolute speed, so raw ratios would trip
//! on hardware, not code. The gate therefore divides each ratio by the
//! *median* ratio across all matched points: a uniformly faster or
//! slower runner cancels out, while a point that regressed relative to
//! its peers stands out. A point fails when its normalised ratio
//! exceeds `--threshold` (default 1.5x). Normalisation alone would be
//! blind to a change that slows *everything* (a shared kernel like
//! `matmul_into` regressing moves the median itself), so a second,
//! looser raw gate backs it up: any point whose raw ratio exceeds
//! `--raw-threshold` (default 3.0x, sized to exceed plausible runner
//! variance) also fails. The full delta table (raw and normalised)
//! prints on every run, pass or fail.
//!
//! Baseline lifecycle: a baseline with `"bootstrap": true` reports but
//! never fails the job — it seeds the trajectory until a PR commits
//! real runner numbers. Individual points may also carry
//! `"bootstrap": true` inside an armed baseline: such points report
//! their ratios but never fail and are excluded from the median
//! normaliser, so a PR can add new bench coverage (seeded with
//! estimates) without disarming the gate for everything else. Either
//! way `--write-merged PATH` emits the current points as a fresh fully
//! armed baseline (CI uploads it as an artifact; copy it over
//! `BENCH_baseline.json` to ratchet). Points present in the baseline
//! but missing from the current runs fail the gate: if a PR changes
//! the bench matrix, it must update the baseline in the same change.
//! The one exception is the long-context tier: baseline ids containing
//! `-long-` only exist when the scheduled `long-bench` job runs its
//! `--long` sweeps, so a smoke run that lacks them reports "skipped"
//! instead of failing, and `--write-merged` carries the baseline's
//! long points forward untouched so the seeds survive the ratchet.

use htransformer::util::bench::Table;
use htransformer::util::cli::Args;
use htransformer::util::json::{num, obj, s, Json};

/// `(id, per_token_us, raw point)` for every point in a trajectory file.
fn load_points(path: &str) -> Result<(Json, Vec<(String, f64, Json)>), String> {
    let text = std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
    let doc = Json::parse(&text).map_err(|e| format!("{path}: {e}"))?;
    let arr = doc
        .get("points")
        .and_then(|p| p.as_arr())
        .ok_or_else(|| format!("{path}: no points[] array"))?;
    let mut out = Vec::with_capacity(arr.len());
    for p in arr {
        let id = p
            .get("id")
            .and_then(|v| v.as_str())
            .ok_or_else(|| format!("{path}: point without an id"))?
            .to_string();
        let us = p
            .get("per_token_us")
            .and_then(|v| v.as_f64())
            .ok_or_else(|| format!("{path}: point {id} without per_token_us"))?;
        out.push((id, us, p.clone()));
    }
    Ok((doc, out))
}

fn median(mut xs: Vec<f64>) -> f64 {
    xs.sort_by(|a, b| a.partial_cmp(b).expect("finite ratios"));
    let n = xs.len();
    if n == 0 {
        return 1.0;
    }
    if n % 2 == 1 {
        xs[n / 2]
    } else {
        0.5 * (xs[n / 2 - 1] + xs[n / 2])
    }
}

fn run() -> Result<i32, String> {
    let args = Args::from_env();
    let baseline_path = args
        .get("baseline")
        .ok_or_else(|| "--baseline PATH is required".to_string())?
        .to_string();
    let threshold = args.f64_or("threshold", 1.5);
    let raw_threshold = args.f64_or("raw-threshold", 3.0);
    // Args puts the first bare argument into `subcommand`; every bare
    // argument is a current-trajectory file for this tool
    let currents: Vec<String> = args
        .subcommand
        .iter()
        .cloned()
        .chain(args.positional.iter().cloned())
        .collect();
    if currents.is_empty() {
        return Err("no current BENCH_*.json files given".to_string());
    }

    let (base_doc, base_points) = load_points(&baseline_path)?;
    let bootstrap = base_doc
        .get("bootstrap")
        .and_then(|b| b.as_bool())
        .unwrap_or(false);

    let mut cur_points: Vec<(String, f64, Json)> = Vec::new();
    let mut cur_commit = "unknown".to_string();
    for path in &currents {
        let (doc, pts) = load_points(path)?;
        if let Some(c) = doc.get("commit").and_then(|v| v.as_str()) {
            cur_commit = c.to_string();
        }
        for (id, us, raw) in pts {
            if cur_points.iter().any(|(i, _, _)| *i == id) {
                return Err(format!("duplicate point id {id} across current files"));
            }
            cur_points.push((id, us, raw));
        }
    }

    // match by id; collect raw ratios for the median normaliser
    let mut matched: Vec<(String, f64, f64, bool)> = Vec::new(); // (id, base, cur, seed)
    let mut missing: Vec<String> = Vec::new();
    let mut long_skipped: Vec<String> = Vec::new();
    for (id, base_us, raw) in &base_points {
        // a per-point bootstrap marker: the baseline value is a seed
        // estimate, not a measurement — report, never fail, and keep
        // it out of the runner-speed normaliser
        let seed = raw.get("bootstrap").and_then(|b| b.as_bool()).unwrap_or(false);
        match cur_points.iter().find(|(i, _, _)| i == id) {
            Some((_, cur_us, _)) => matched.push((id.clone(), *base_us, *cur_us, seed)),
            // long-tier points only exist when the scheduled job ran
            // the `--long` sweeps — absence from a smoke run is expected
            None if id.contains("-long-") => long_skipped.push(id.clone()),
            None => missing.push(id.clone()),
        }
    }
    let fresh: Vec<&String> = cur_points
        .iter()
        .map(|(id, _, _)| id)
        .filter(|id| !base_points.iter().any(|(b, _, _)| b == *id))
        .collect();
    let m = median(
        matched
            .iter()
            .filter(|(_, _, _, seed)| !seed)
            .map(|(_, b, c, _)| c / b.max(1e-9))
            .collect(),
    );

    println!(
        "bench_compare: {} matched point(s), median speed ratio {m:.3} \
         (runner-speed normaliser), threshold {threshold:.2}x normalised / \
         {raw_threshold:.2}x raw",
        matched.len()
    );
    let mut t = Table::new(&["point", "baseline", "current", "ratio", "normalised", "verdict"]);
    let mut regressed = 0usize;
    for (id, base_us, cur_us, seed) in &matched {
        let ratio = cur_us / base_us.max(1e-9);
        let norm = ratio / m.max(1e-9);
        let verdict = if *seed {
            // seed estimate: informational until measured numbers land
            "bootstrap"
        } else if norm > threshold {
            regressed += 1;
            "REGRESSED"
        } else if ratio > raw_threshold {
            // normalisation hides uniform slowdowns (a shared kernel
            // regressing moves the median too) — the raw cap catches them
            regressed += 1;
            "REGRESSED (raw)"
        } else if norm < 1.0 / threshold {
            "improved"
        } else {
            "ok"
        };
        t.row(&[
            id.clone(),
            format!("{base_us:.1}µs"),
            format!("{cur_us:.1}µs"),
            format!("{ratio:.2}x"),
            format!("{norm:.2}x"),
            verdict.to_string(),
        ]);
    }
    for id in &missing {
        t.row(&[
            id.clone(),
            "-".to_string(),
            "MISSING".to_string(),
            "-".to_string(),
            "-".to_string(),
            "FAIL".to_string(),
        ]);
    }
    for id in &long_skipped {
        t.row(&[
            id.clone(),
            "-".to_string(),
            "-".to_string(),
            "-".to_string(),
            "-".to_string(),
            "skipped (long tier)".to_string(),
        ]);
    }
    for id in &fresh {
        t.row(&[
            (*id).clone(),
            "new".to_string(),
            "-".to_string(),
            "-".to_string(),
            "-".to_string(),
            "new point".to_string(),
        ]);
    }
    t.print();

    if let Some(path) = args.get("write-merged") {
        // a smoke run has no long-tier measurements; keep the
        // baseline's long seeds alive across the ratchet
        let mut merged_points: Vec<Json> =
            cur_points.iter().map(|(_, _, raw)| raw.clone()).collect();
        for (id, _, raw) in &base_points {
            if long_skipped.contains(id) {
                merged_points.push(raw.clone());
            }
        }
        let merged = obj(vec![
            ("bench", s("baseline")),
            ("commit", s(&cur_commit)),
            ("bootstrap", Json::Bool(false)),
            ("threshold", num(threshold)),
            ("points", Json::Arr(merged_points)),
        ]);
        std::fs::write(path, merged.to_string()).map_err(|e| format!("{path}: {e}"))?;
        println!("wrote candidate baseline {path} (commit {cur_commit})");
    }

    let failures = regressed + missing.len();
    if failures > 0 {
        if bootstrap {
            println!(
                "\n{failures} finding(s), but the committed baseline is a bootstrap seed — \
                 not failing the job. Commit the candidate baseline to arm the gate."
            );
            return Ok(0);
        }
        println!(
            "\nFAIL: {regressed} point(s) regressed (past {threshold:.2}x normalised or \
             {raw_threshold:.2}x raw) and {} expected point(s) are missing. If the bench \
             matrix changed on purpose, update BENCH_baseline.json in the same PR.",
            missing.len()
        );
        return Ok(1);
    }
    println!(
        "\nOK: no per-token regression past {threshold:.2}x normalised ({raw_threshold:.2}x raw)."
    );
    Ok(0)
}

fn main() {
    match run() {
        Ok(code) => std::process::exit(code),
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    }
}
