//! Batched-serving demo: start the inference server on an encoder model,
//! drive it with concurrent client threads, and report the dynamic
//! batcher's latency/throughput profile.
//!
//!   cargo run --release --example serve_batch -- [--clients 8]
//!       [--requests 16] [--max-wait-ms 5] [--model lra_listops_h1d]

use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{bail, Result};
use htransformer::coordinator::server::{start, ServeOptions};
use htransformer::data;
use htransformer::runtime::default_artifacts_dir;
use htransformer::util::cli::Args;
use htransformer::util::Rng;

fn main() -> Result<()> {
    let args = Args::parse(&std::env::args().skip(1).collect::<Vec<_>>());
    let model = args.str_or("model", "lra_listops_h1d");
    let n_clients = args.usize_or("clients", 8);
    let per_client = args.usize_or("requests", 16);

    let handle = Arc::new(start(
        default_artifacts_dir(),
        model.clone(),
        ServeOptions {
            max_wait: Duration::from_millis(args.u64_or("max-wait-ms", 5)),
            seed: 42,
            checkpoint: args.get("checkpoint").map(std::path::PathBuf::from),
        },
    )?);
    if !handle.wait_ready(Duration::from_secs(180)) {
        bail!("server did not become ready (artifacts missing?)");
    }
    let seq = handle.seq_len;
    println!("serving {model} (L={seq}); {n_clients} clients x {per_client} requests");

    let t0 = Instant::now();
    let mut threads = Vec::new();
    for c in 0..n_clients {
        let h = Arc::clone(&handle);
        threads.push(std::thread::spawn(move || -> Result<usize, String> {
            let gen = data::make_task("listops", seq);
            let mut rng = Rng::new(c as u64 + 1);
            let mut classified = 0usize;
            for _ in 0..per_client {
                let ex = gen.sample(&mut rng);
                let resp = h.infer(ex.tokens).map_err(|e| e.to_string())?;
                // logits are [n_classes]; count argmax as a served result
                let pred = resp
                    .logits
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .map(|(i, _)| i)
                    .unwrap_or(0);
                if pred == ex.label as usize {
                    classified += 1;
                }
            }
            Ok(classified)
        }));
    }
    let mut total_correct = 0usize;
    for t in threads {
        total_correct += t.join().expect("client thread").map_err(anyhow::Error::msg)?;
    }
    let wall = t0.elapsed().as_secs_f64();

    let s = handle.stats();
    let total = n_clients * per_client;
    println!("\n== serving profile ==");
    println!("requests          : {total}");
    println!("throughput        : {:.1} req/s", total as f64 / wall);
    println!("batches           : {} (mean fill {:.2})", s.batches, s.mean_batch_fill);
    println!("latency p50 / p99 : {:.1}ms / {:.1}ms", s.p50_latency * 1e3, s.p99_latency * 1e3);
    println!("exec mean         : {:.1}ms", s.exec_mean * 1e3);
    println!(
        "(untrained model — argmax accuracy {:.2} is chance; the demo measures the serving path)",
        total_correct as f64 / total as f64
    );
    Ok(())
}
