//! The HTTP serving front end, end to end in one process — no
//! artifacts, no python, no external crates. Starts `model::net`'s
//! server on a loopback port with two sharded engine workers, streams
//! a few concurrent generations through real sockets with the
//! built-in blocking client, injects a malformed request and a
//! mid-stream disconnect, then drains gracefully and prints the final
//! `/metrics` snapshot.
//!
//!   cargo run --release --example cpu_serve_net
//!
//! The same server is `htx serve --listen 127.0.0.1:8080` from the
//! CLI; talk to it with curl:
//!
//!   curl -N -d '{"prompt":[1,2,3],"max_new":16}' \
//!        http://127.0.0.1:8080/generate
//!   curl http://127.0.0.1:8080/metrics
//!
//! Flag-by-flag server reference and tuning guide:
//! docs/OPERATIONS.md; stack walkthrough: docs/ARCHITECTURE.md.

use std::sync::Arc;
use std::time::Duration;

use htransformer::model::net::client;
use htransformer::model::{
    run_sequential, synthetic_workload, AttnSpec, Model, ModelConfig, NetConfig, NetServer,
    ServeConfig,
};

fn main() -> Result<(), String> {
    let cfg = ModelConfig {
        vocab_size: 512,
        d_model: 128,
        n_heads: 4,
        n_layers: 2,
        d_ff: 512,
        max_len: 96,
        causal: true,
        attention: AttnSpec::H1d { nr: 16 },
        quant_weights: false,
    };
    let model = Arc::new(Model::new(cfg, 42)?);
    println!(
        "model: {} params, attention {} (causal)",
        model.n_params(),
        model.attention_name()
    );

    let server = NetServer::start(
        Arc::clone(&model),
        "127.0.0.1:0",
        NetConfig {
            workers: 2,
            serve: ServeConfig {
                max_batch: 4,
                threads: 1,
                ..ServeConfig::default()
            },
            ..NetConfig::default()
        },
    )?;
    let addr = server.local_addr().to_string();
    println!("listening on {addr} (2 engine workers, per-worker page pools)");

    // six concurrent clients stream chunked NDJSON over the loopback;
    // the sequential oracle pins every token they receive
    let requests = synthetic_workload(6, &[16, 32], 12, model.cfg.vocab_size, 0.0, 7);
    let oracle = run_sequential(&model, &requests)?;
    let handles: Vec<_> = requests
        .iter()
        .map(|r| {
            let (addr, r) = (addr.clone(), r.clone());
            std::thread::spawn(move || {
                (r.id, client::generate(&addr, &r.prompt, r.max_new, 0.0, r.seed))
            })
        })
        .collect();
    // ...and two misbehaving ones: a malformed body and a client that
    // hangs up after two streamed tokens (its session's pages release)
    let bad = client::raw(
        &addr,
        &format!("POST /generate HTTP/1.1\r\nHost: {addr}\r\nContent-Length: 8\r\n\r\nnot json"),
    )?;
    println!("malformed request answered {}", bad.status);
    let dropped = client::generate_and_disconnect(&addr, &[1, 2, 3, 4], 24, 9, 2)?;
    println!("disconnected after {} streamed token(s)", dropped.len());

    let want: std::collections::BTreeMap<u64, &[u32]> =
        oracle.completions.iter().map(|c| (c.id, c.tokens.as_slice())).collect();
    let mut streamed = 0usize;
    for h in handles {
        let (id, got) = h.join().expect("client thread");
        let got = got?;
        assert_eq!(got, want[&id], "request {id}: wire stream diverged from the oracle");
        streamed += got.len();
    }
    println!("{streamed} tokens streamed over the wire, all bitwise the sequential oracle's");

    // let the cancelled session's pages drain, then shut down cleanly
    std::thread::sleep(Duration::from_millis(50));
    let metrics = server.shutdown();
    println!("final /metrics snapshot:\n{}", metrics.to_string());
    Ok(())
}
