//! LRA ListOps (the paper's flagship hierarchical-reasoning task): train
//! the h1d encoder and the quadratic baseline on the same generated data
//! and compare accuracy — a scaled-down Table-1 cell.
//!
//!   cargo run --release --example lra_listops -- [--steps 150]

use anyhow::{Context, Result};
use htransformer::coordinator::{
    schedule::LrSchedule, spawn_source_for, TrainOptions, Trainer,
};
use htransformer::runtime::{default_artifacts_dir, Manifest};
use htransformer::util::bench::Table;
use htransformer::util::cli::Args;

fn train_one(manifest: &Manifest, model: &str, steps: usize) -> Result<(f64, f64, f64)> {
    let mut trainer = Trainer::new(manifest, model, 1)?;
    let opts = TrainOptions {
        steps,
        schedule: LrSchedule::WarmupCosine {
            warmup: steps / 10,
            total: steps,
            peak: 2e-3,
            floor: 1e-4,
        },
        seed: 7,
        log_every: (steps / 5).max(1),
        eval_every: 0,
        eval_batches: 4,
        checkpoint_path: None,
        verbose: true,
    };
    let train_src = spawn_source_for(&trainer.model, 7, 4);
    let eval_src = spawn_source_for(&trainer.model, 991, 2);
    println!("-- {model} --");
    let report = trainer.run(&train_src, None, &opts)?;
    let ev = trainer.evaluate(&eval_src, 8)?;
    Ok((ev.accuracy, ev.mean_nll, report.steps_per_sec))
}

fn main() -> Result<()> {
    let args = Args::parse(&std::env::args().skip(1).collect::<Vec<_>>());
    let steps = args.usize_or("steps", 150);
    let manifest = Manifest::load(default_artifacts_dir())
        .context("run `make artifacts` first")?;

    let (acc_h, nll_h, sps_h) = train_one(&manifest, "lra_listops_h1d", steps)?;
    let (acc_f, nll_f, sps_f) = train_one(&manifest, "lra_listops_full", steps)?;

    let mut t = Table::new(&["model", "eval acc", "eval loss", "steps/s"]);
    t.row(&["h1d (Nr=16)".into(), format!("{acc_h:.3}"), format!("{nll_h:.3}"), format!("{sps_h:.2}")]);
    t.row(&["full (baseline)".into(), format!("{acc_f:.3}"), format!("{nll_f:.3}"), format!("{sps_f:.2}")]);
    println!();
    t.print();
    println!("\nchance accuracy is 0.10 (10 classes); both models should beat it,");
    println!("and h1d should be competitive with the quadratic baseline (Table 1).");
    Ok(())
}
