//! Continuous-batching serving on the CPU stack — no artifacts, no
//! python, no xla feature. Queues a small closed-loop workload through
//! `model::serve`'s scheduler and compares aggregate throughput against
//! the sequential one-session-at-a-time loop.
//!
//!   cargo run --release --example cpu_serve
//!
//! How the engine works inside (paged KV, radix prefix cache,
//! speculative decoding): docs/ARCHITECTURE.md.

use std::sync::Arc;

use htransformer::model::{
    run_sequential, synthetic_workload, AttnSpec, Model, ModelConfig, ServeConfig, ServeEngine,
};

fn main() -> Result<(), String> {
    let cfg = ModelConfig {
        vocab_size: 512,
        d_model: 128,
        n_heads: 4,
        n_layers: 2,
        d_ff: 512,
        max_len: 96,
        causal: true,
        attention: AttnSpec::H1d { nr: 16 },
        quant_weights: false,
    };
    let model = Arc::new(Model::new(cfg, 42)?);
    println!(
        "model: {} params, attention {} (causal)",
        model.n_params(),
        model.attention_name()
    );

    let requests = synthetic_workload(12, &[16, 32, 48], 16, model.cfg.vocab_size, 0.0, 7);
    let seq = run_sequential(&model, &requests)?;
    let mut engine = ServeEngine::new(
        Arc::clone(&model),
        ServeConfig {
            max_batch: 8,
            max_tokens: usize::MAX,
            threads: htransformer::util::threadpool::default_threads(),
            ..ServeConfig::default()
        },
    )?;
    let batched = engine.run(requests)?;

    for (mode, rep) in [("sequential", &seq), ("continuous", &batched)] {
        println!(
            "{mode:>10}: {:>6.0} tokens/s, per-token {:.1}µs (p95 {:.1}µs), \
             mean occupancy {:.2}",
            rep.stats.tokens_per_sec(),
            rep.stats.per_token_us(),
            rep.stats.latency_us(95.0),
            rep.stats.mean_occupancy()
        );
    }
    println!(
        "speedup: {:.2}x aggregate throughput at max_batch 8",
        batched.stats.tokens_per_sec() / seq.stats.tokens_per_sec().max(1e-9)
    );
    Ok(())
}
