//! End-to-end driver (DESIGN.md requirement): train the decoder language
//! model through the full three-layer stack for several hundred steps on
//! the synthetic corpus, logging the loss curve and final perplexity.
//!
//!   cargo run --release --example lm_tiny -- [--model lm_tiny_h1d]
//!       [--steps 300] [--lr 1e-3] [--eval-every 50] [--ckpt out.bin]
//!
//! The end-to-end run indexed in DESIGN.md used the defaults.

use anyhow::{Context, Result};
use htransformer::coordinator::{
    schedule::LrSchedule, spawn_source_for, TrainOptions, Trainer,
};
use htransformer::runtime::{default_artifacts_dir, Manifest};
use htransformer::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::parse(&std::env::args().skip(1).collect::<Vec<_>>());
    let model = args.str_or("model", "lm_tiny_h1d");
    let steps = args.usize_or("steps", 300);
    let lr = args.f64_or("lr", 1e-3);

    let manifest = Manifest::load(default_artifacts_dir())
        .context("run `make artifacts` first")?;
    let mut trainer = Trainer::new(&manifest, &model, 42)?;
    println!(
        "== E2E: training {model} ==\n\
         params: {}  attention: {}  Nr: {}  L: {}  batch: {}",
        trainer.n_params(),
        trainer.model.config.attention,
        trainer.model.config.block_size,
        trainer.model.config.max_len,
        trainer.model.batch,
    );

    let opts = TrainOptions {
        steps,
        schedule: LrSchedule::WarmupCosine {
            warmup: steps / 10,
            total: steps,
            peak: lr,
            floor: lr * 0.05,
        },
        seed: 42,
        log_every: args.usize_or("log-every", 10),
        eval_every: args.usize_or("eval-every", 50),
        eval_batches: 4,
        checkpoint_path: args.get("ckpt").map(std::path::PathBuf::from),
        verbose: true,
    };
    let train_src = spawn_source_for(&trainer.model, 42, 4);
    let eval_src = spawn_source_for(&trainer.model, 777, 2);

    // baseline perplexity at init
    let ev0 = trainer.evaluate(&eval_src, 4)?;
    println!("init perplexity: {:.2}", ev0.perplexity());

    let report = trainer.run(&train_src, Some(&eval_src), &opts)?;
    let ev = trainer.evaluate(&eval_src, 8)?;

    println!("\n== loss curve ==");
    for (s, l) in &report.losses {
        println!("{s:>6} {l:.4}");
    }
    println!("\n== summary ==");
    println!("steps/sec        : {:.3}", report.steps_per_sec);
    println!("final train loss : {:.4}", report.final_loss);
    println!("init  ppl        : {:.2}", ev0.perplexity());
    println!("final ppl        : {:.2}", ev.perplexity());
    assert!(
        ev.perplexity() < ev0.perplexity() * 0.5,
        "training must at least halve perplexity"
    );
    println!("lm_tiny E2E OK");
    Ok(())
}
