//! End-to-end CPU inference — no `xla` feature, no `make artifacts`.
//!
//! Builds a small causal h1d transformer from the `model` stack, runs a
//! batch of token sequences through it, and demonstrates the workspace
//! steady state: the second same-shape forward reuses every buffer
//! (pointer/capacity snapshot unchanged) and reproduces the first
//! call's logits bit for bit.
//!
//!     cargo run --release --example cpu_infer

use htransformer::model::{AttnSpec, Model, ModelConfig, ModelWorkspace};
use htransformer::util::Rng;

fn main() {
    let cfg = ModelConfig {
        vocab_size: 256,
        d_model: 64,
        n_heads: 4,
        n_layers: 2,
        d_ff: 256,
        max_len: 256,
        causal: true,
        attention: AttnSpec::H1d { nr: 16 },
        quant_weights: false,
    };
    let model = Model::new(cfg, 42).expect("valid config");
    println!(
        "h1d decoder: {} params, attention = {}",
        model.n_params(),
        model.attention_name()
    );

    let (batch, len) = (2usize, 128usize);
    let mut rng = Rng::new(7);
    let tokens: Vec<u32> = (0..batch * len)
        .map(|_| rng.below(model.cfg.vocab_size as u64) as u32)
        .collect();

    let mut ws = ModelWorkspace::parallel();
    let t0 = std::time::Instant::now();
    let first = model.forward(&mut ws, &tokens, batch).clone();
    let cold = t0.elapsed();
    println!(
        "forward: [{batch}, {len}] tokens -> [{}, {}] logits in {:.1?} (cold, allocates the arena)",
        first.rows, first.cols, cold
    );

    let snapshot = ws.capacity_snapshot();
    let t1 = std::time::Instant::now();
    let second = model.forward(&mut ws, &tokens, batch).clone();
    let warm = t1.elapsed();
    assert_eq!(
        ws.capacity_snapshot(),
        snapshot,
        "second same-shape forward must not allocate"
    );
    assert_eq!(first.data, second.data, "reuse must not change results");
    println!("repeat:  same shape in {warm:.1?} (warm, zero workspace allocations)");

    for bi in 0..batch {
        let last = first.row((bi + 1) * len - 1);
        let (mut arg, mut best) = (0usize, f32::NEG_INFINITY);
        for (j, &v) in last.iter().enumerate() {
            if v > best {
                best = v;
                arg = j;
            }
        }
        println!("seq {bi}: next-token argmax {arg} (logit {best:.4})");
    }
    println!("ok: CPU inference end-to-end with no xla feature and no artifacts");
}
