//! KV-cached autoregressive generation — no `xla` feature, no
//! `make artifacts`.
//!
//! Builds a small causal h1d decoder, prefills a prompt once, then
//! generates token by token through `DecodeSession::step` — each step
//! pays one token's work (h1d: O(Nr·d·log L) attention), not a full
//! forward over the growing context. Along the way it demonstrates the
//! two decode contracts the test suite pins:
//!
//!  * prefix parity: a depth-1 session's logits match a from-scratch
//!    `Model::forward` over the same tokens (deeper h1d stacks decode
//!    with standard online KV-cache semantics — see
//!    `model::decode`'s docs and `tests/decode_parity.rs`);
//!  * zero-alloc steps: the workspace snapshot is unchanged across
//!    steps, and a recycled workspace starts the next session without
//!    re-growing the arena.
//!
//!     cargo run --release --example cpu_generate

use htransformer::model::{sample_logits, AttnSpec, Model, ModelConfig, ModelWorkspace};
use htransformer::util::Rng;

fn main() {
    let cfg = ModelConfig {
        vocab_size: 256,
        d_model: 64,
        n_heads: 4,
        n_layers: 2,
        d_ff: 256,
        max_len: 256,
        causal: true,
        attention: AttnSpec::H1d { nr: 16 },
        quant_weights: false,
    };
    let model = Model::new(cfg, 42).expect("valid config");
    println!(
        "h1d decoder: {} params, attention = {}",
        model.n_params(),
        model.attention_name()
    );

    let mut rng = Rng::new(7);
    let prompt: Vec<u32> = (0..32)
        .map(|_| rng.below(model.cfg.vocab_size as u64) as u32)
        .collect();

    let t0 = std::time::Instant::now();
    let mut session = model.prefill(&prompt).expect("prefill");
    println!(
        "prefill: {} prompt tokens in {:.1?} (one batched forward, KV caches loaded)",
        prompt.len(),
        t0.elapsed()
    );

    let n_gen = 48usize;
    let mut generated = prompt.clone();
    let mut next = sample_logits(session.logits().row(0), 0.8, &mut rng) as u32;
    let snapshot = session.capacity_snapshot();
    let t1 = std::time::Instant::now();
    for _ in 0..n_gen {
        generated.push(next);
        let logits = session.step(next).expect("within max_len");
        next = sample_logits(logits.row(0), 0.8, &mut rng) as u32;
    }
    let dt = t1.elapsed();
    assert_eq!(
        session.capacity_snapshot(),
        snapshot,
        "decode steps must not allocate"
    );
    println!(
        "decode: {n_gen} tokens in {dt:.1?} ({:.1}µs/token, zero workspace allocations)",
        dt.as_secs_f64() * 1e6 / n_gen as f64
    );
    println!(
        "sampled ids: {}",
        generated[prompt.len()..]
            .iter()
            .map(|t| t.to_string())
            .collect::<Vec<_>>()
            .join(" ")
    );

    // prefix parity in its exact regime (depth 1, where the KV cache
    // holds projections of the embeddings that no later token changes):
    // the incremental path reproduces a from-scratch forward
    let shallow = Model::new(
        ModelConfig {
            n_layers: 1,
            ..model.cfg.clone()
        },
        42,
    )
    .expect("valid config");
    let probe = &generated[..48];
    let mut ws = ModelWorkspace::serial();
    let full = shallow.forward(&mut ws, probe, 1);
    let mut s1 = shallow.prefill(&probe[..8]).expect("prefill");
    for &t in &probe[8..] {
        s1.step(t).expect("within max_len");
    }
    let mut max_diff = 0.0f32;
    for j in 0..full.cols {
        max_diff = max_diff.max((full.at(full.rows - 1, j) - s1.logits().at(0, j)).abs());
    }
    assert!(max_diff < 1e-4, "prefix parity violated: {max_diff}");
    println!("parity: depth-1 step logits match a full forward (max diff {max_diff:.2e})");

    // recycle the arena into a second session: no re-growth
    let ws2 = session.into_workspace();
    let session2 = model.prefill_with(ws2, &prompt).expect("prefill");
    println!(
        "recycled workspace into a new session at pos {} (arena reused)",
        session2.pos()
    );
    println!("ok: KV-cached generation end-to-end with no xla feature and no artifacts");
}
