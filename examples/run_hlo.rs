//! Generic HLO-text runner/bencher — a debugging & perf utility.
//!
//!   run_hlo <file.hlo.txt> <in1.f32:1x4x256x32> [...]
//!       [--bench N]    time N executions (prints min/mean)
//!       [--dump]       write outputs to /tmp/hlo_out_<i>.f32
//!
//! Inputs are raw little-endian f32 files with an explicit shape suffix.

use std::io::Write;
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let mut args: Vec<String> = std::env::args().skip(1).collect();
    let mut bench_iters = 0usize;
    let mut dump = false;
    if let Some(i) = args.iter().position(|a| a == "--bench") {
        bench_iters = args[i + 1].parse()?;
        args.drain(i..=i + 1);
    }
    if let Some(i) = args.iter().position(|a| a == "--dump") {
        dump = true;
        args.remove(i);
    }
    let client = xla::PjRtClient::cpu()?;
    let t0 = Instant::now();
    let proto = xla::HloModuleProto::from_text_file(&args[0])?;
    let comp = xla::XlaComputation::from_proto(&proto);
    let exe = client.compile(&comp)?;
    eprintln!("compiled in {:?}", t0.elapsed());

    let mut lits = Vec::new();
    for spec in &args[1..] {
        let (path, shape) = spec
            .split_once(':')
            .ok_or_else(|| anyhow::anyhow!("input spec must be file:shape"))?;
        let dims: Vec<i64> = shape.split('x').map(|s| s.parse().unwrap()).collect();
        let bytes = std::fs::read(path)?;
        let data: Vec<f32> = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        lits.push(xla::Literal::vec1(&data).reshape(&dims)?);
    }

    if bench_iters > 0 {
        // warmup
        for _ in 0..3 {
            let _ = exe.execute::<xla::Literal>(&lits)?;
        }
        let mut min = f64::INFINITY;
        let mut total = 0.0;
        for _ in 0..bench_iters {
            let t = Instant::now();
            let r = exe.execute::<xla::Literal>(&lits)?;
            let dt = t.elapsed().as_secs_f64();
            std::hint::black_box(&r);
            min = min.min(dt);
            total += dt;
        }
        println!(
            "bench: min {:.3}ms mean {:.3}ms over {} iters",
            min * 1e3,
            total / bench_iters as f64 * 1e3,
            bench_iters
        );
    }

    let result = exe.execute::<xla::Literal>(&lits)?[0][0].to_literal_sync()?;
    let mut tup = result;
    let parts = tup.decompose_tuple()?;
    for (i, p) in parts.iter().enumerate() {
        let v = p.to_vec::<f32>()?;
        if dump {
            let mut f = std::fs::File::create(format!("/tmp/hlo_out_{i}.f32"))?;
            for x in &v {
                f.write_all(&x.to_le_bytes())?;
            }
        }
        println!("out {i}: {} elems", v.len());
    }
    Ok(())
}
