//! Autoregressive generation from a trained LM checkpoint — exercises
//! the fwd artifact on the serving path (greedy or temperature sampling).
//!
//!   cargo run --release --example lm_tiny -- --steps 300 --ckpt lm.ckpt
//!   cargo run --release --example lm_generate -- --checkpoint lm.ckpt \
//!       [--tokens 48] [--temperature 0.8] [--model lm_tiny_h1d]
//!
//! The synthetic corpus has no surface forms, so tokens render as
//! `w<id>`; the point demonstrated is the full decode loop (prefix →
//! logits → sample → append) running against the compiled artifact with
//! the coordinator's checkpoint machinery.

use anyhow::{Context, Result};
use htransformer::coordinator::Checkpoint;
use htransformer::runtime::{default_artifacts_dir, Engine, HostTensor, Manifest};
use htransformer::util::cli::Args;
use htransformer::util::Rng;

fn main() -> Result<()> {
    let args = Args::parse(&std::env::args().skip(1).collect::<Vec<_>>());
    let model_name = args.str_or("model", "lm_tiny_h1d");
    let n_new = args.usize_or("tokens", 48);
    let temperature = args.f64_or("temperature", 0.8) as f32;

    let manifest = Manifest::load(default_artifacts_dir())?;
    let model = manifest.model(&model_name)?.clone();
    let mut engine = Engine::cpu()?;
    let fwd = engine.load(
        &format!("{model_name}.fwd"),
        model.artifacts.get("fwd").context("fwd artifact")?,
    )?;
    let init = engine.load(
        &format!("{model_name}.init"),
        model.artifacts.get("init").context("init artifact")?,
    )?;

    // parameters: fresh init, optionally overlaid from a checkpoint
    let mut params = init.run(&[HostTensor::scalar_i32(42)])?;
    if let Some(ck) = args.get("checkpoint") {
        let ckpt = Checkpoint::load(std::path::Path::new(ck))?;
        let by_name = ckpt.by_name();
        for (i, (name, _)) in model.params.iter().enumerate() {
            if let Some(t) = by_name.get(format!("p.{name}").as_str()) {
                params[i] = (*t).clone();
            }
        }
        println!("loaded checkpoint from step {}", ckpt.step);
    } else {
        println!("(no --checkpoint: generating from a random init)");
    }

    let (batch, seq) = (model.batch, model.config.max_len);
    let vocab = model.config.vocab_size;
    let mut rng = Rng::new(args.u64_or("seed", 7));

    // decode loop: BOS prefix, argmax/temperature-sample the next token
    let mut ids: Vec<i32> = vec![1]; // BOS
    for _ in 0..n_new {
        let prefix = ids.len().min(seq);
        let mut tokens = vec![0i32; batch * seq];
        tokens[..prefix].copy_from_slice(&ids[ids.len() - prefix..]);
        let mut inputs: Vec<&HostTensor> = params.iter().collect();
        let tok_t = HostTensor::i32(vec![batch, seq], tokens);
        inputs.push(&tok_t);
        let out = fwd.run_refs(&inputs)?;
        let logits = out[0].as_f32()?; // [batch, seq, vocab]
        let row = &logits[(prefix - 1) * vocab..prefix * vocab];

        let next = if temperature <= 0.0 {
            row.iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .map(|(i, _)| i)
                .unwrap()
        } else {
            // temperature softmax sampling (skip PAD=0)
            let mx = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let weights: Vec<f64> = row
                .iter()
                .enumerate()
                .map(|(i, &x)| {
                    if i == 0 {
                        0.0
                    } else {
                        (((x - mx) / temperature) as f64).exp()
                    }
                })
                .collect();
            rng.weighted(&weights)
        };
        ids.push(next as i32);
    }

    let rendered: Vec<String> = ids
        .iter()
        .map(|&t| match t {
            0 => "<pad>".into(),
            1 => "<bos>".into(),
            2 => ".".into(),
            t => format!("w{t}"),
        })
        .collect();
    println!("\ngenerated {} tokens:\n{}", n_new, rendered.join(" "));

    // sanity: a trained model should produce sentence structure (EOS
    // tokens); an untrained one mostly won't — report either way
    let eos = ids.iter().filter(|&&t| t == 2).count();
    println!("\nsentence terminators in sample: {eos}");
    Ok(())
}
