//! Quickstart: load the AOT-compiled hierarchical-attention artifact,
//! run it through PJRT, and cross-check the numbers against the pure-rust
//! mirror implementation — the smallest end-to-end proof that all three
//! layers (Pallas kernel → JAX lowering → rust runtime) compose.
//!
//!   make artifacts && cargo run --release --example quickstart

use anyhow::{Context, Result};
use htransformer::attention::{Attention, Full, H1d};
use htransformer::runtime::{default_artifacts_dir, Engine, HostTensor, Manifest};
use htransformer::tensor::Mat;
use htransformer::util::Rng;

fn main() -> Result<()> {
    let manifest = Manifest::load(default_artifacts_dir())
        .context("run `make artifacts` first")?;
    let mut engine = Engine::cpu()?;
    println!("PJRT platform: {}", engine.platform());

    // pick the L=256 h1d artifact and its quadratic sibling
    let entry = &manifest.attention["attn_h1d_L256"];
    let full_entry = &manifest.attention["attn_full_L256"];
    let (b, h, l, d, nr) = (entry.batch, entry.heads, entry.seq_len, entry.d_head, entry.nr);
    println!("artifact attn_h1d_L256: [B={b}, H={h}, L={l}, d={d}], Nr={nr}");

    let exe = engine.load(&entry.name, &entry.sig)?;
    let exe_full = engine.load(&full_entry.name, &full_entry.sig)?;
    println!(
        "compiled in {:.0}ms / {:.0}ms",
        exe.compile_secs * 1e3,
        exe_full.compile_secs * 1e3
    );

    // random inputs
    let mut rng = Rng::new(2024);
    let n = b * h * l * d;
    let mk = |rng: &mut Rng| {
        let mut v = vec![0f32; n];
        rng.fill_normal(&mut v, 1.0);
        HostTensor::f32(vec![b, h, l, d], v)
    };
    let (q, k, v) = (mk(&mut rng), mk(&mut rng), mk(&mut rng));

    // run the compiled XLA programs
    let t0 = std::time::Instant::now();
    let z_h1d = &exe.run(&[q.clone(), k.clone(), v.clone()])?[0];
    let t_h1d = t0.elapsed();
    let t0 = std::time::Instant::now();
    let z_full = &exe_full.run(&[q.clone(), k.clone(), v.clone()])?[0];
    let t_full = t0.elapsed();

    // mirror in pure rust, head by head
    let qd = q.as_f32()?;
    let kd = k.as_f32()?;
    let vd = v.as_f32()?;
    let zd = z_h1d.as_f32()?;
    let zf = z_full.as_f32()?;
    let rust_h1d = H1d::new(nr);
    let rust_full = Full;
    let mut max_diff = 0f32;
    let mut cos_vs_full = 0f64;
    for head in 0..(b * h) {
        let off = head * l * d;
        let qm = Mat::from_vec(l, d, qd[off..off + l * d].to_vec());
        let km = Mat::from_vec(l, d, kd[off..off + l * d].to_vec());
        let vm = Mat::from_vec(l, d, vd[off..off + l * d].to_vec());
        let z_rust = rust_h1d.forward(&qm, &km, &vm, false);
        let z_xla = Mat::from_vec(l, d, zd[off..off + l * d].to_vec());
        max_diff = max_diff.max(z_rust.max_abs_diff(&z_xla));
        // approximation quality vs exact attention (paper's premise)
        let z_exact = rust_full.forward(&qm, &km, &vm, false);
        cos_vs_full += htransformer::attention::mean_row_cosine(&z_xla, &z_exact);
        // and the XLA full-attention output should match rust full exactly
        let z_xla_full = Mat::from_vec(l, d, zf[off..off + l * d].to_vec());
        assert!(
            z_exact.max_abs_diff(&z_xla_full) < 1e-3,
            "full-attention mismatch"
        );
    }
    cos_vs_full /= (b * h) as f64;

    println!("xla(h1d)  vs rust(h1d): max |diff| = {max_diff:.2e}  (same algorithm, two stacks)");
    println!("xla(h1d)  vs exact attention: mean row cosine = {cos_vs_full:.4}");
    println!("wallclock: h1d {t_h1d:?}  vs full {t_full:?}  at L={l}");
    assert!(max_diff < 1e-3, "cross-language mismatch");
    println!("quickstart OK");
    Ok(())
}
