"""Layer-1: Pallas banded block-attention kernel.

This is the compute hot-spot of H-Transformer-1D: at every hierarchy
level, each query block of ``Nr`` rows attends to (at most) three
neighbouring key/value blocks — the block-tridiagonal band at level 0
and the super/sub-diagonal band at coarse levels (paper Eq. 21-23).

TPU mapping (see DESIGN.md §Hardware-Adaptation): the grid iterates over
``(batch*heads, block)``; BlockSpec stages the ``Nr x d`` query tile and
its 2-3 neighbouring ``Nr x d`` key/value tiles through VMEM; each
``Nr x Nr`` score tile is one MXU matmul; the overlap-quadrant, causal
and validity masks are iota-generated in-register, so no mask tensors
ever touch HBM.  VMEM footprint per program is
``(1 + 2*bands) * Nr * d * 4B + bands * Nr^2 * 4B`` — about 120 KiB for
``Nr = d = 64``, far below the ~16 MiB VMEM budget, leaving room for
double-buffering the sequential grid dimension.

The kernel MUST run with ``interpret=True`` here: real TPU lowering
emits a Mosaic custom-call that the CPU PJRT plugin cannot execute.
Correctness is pinned against the pure-numpy oracle in ``ref.py`` and
the jnp path in ``hattention.py`` by the pytest suite.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG = -1e30


def _directions(level: int, causal: bool):
    if causal:
        return (-1, 0) if level == 0 else (-1,)
    return (-1, 0, 1) if level == 0 else (-1, 1)


def _band_kernel(*refs, nr: int, d: int, nb: int, level: int, causal: bool):
    """Kernel body. refs = [q, k_b0..k_bn, v_b0.., c_b0.., y, den, m]."""
    dirs = _directions(level, causal)
    nd = len(dirs)
    q_ref = refs[0]
    k_refs = refs[1 : 1 + nd]
    v_refs = refs[1 + nd : 1 + 2 * nd]
    c_refs = refs[1 + 2 * nd : 1 + 3 * nd]
    y_ref, den_ref, m_ref = refs[1 + 3 * nd :]

    i = pl.program_id(1)
    q = q_ref[0]  # [nr, d]
    scale = 1.0 / math.sqrt(d)

    rows = jax.lax.broadcasted_iota(jnp.int32, (nr, nr), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (nr, nr), 1)
    half = nr // 2

    scores = []
    for direction, k_ref, c_ref in zip(dirs, k_refs, c_refs):
        k = k_ref[0]  # [nr, d]
        c = c_ref[0]  # [nr]
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        # static masks for this (level, direction)
        if level == 0:
            if causal and direction == 0:
                s = jnp.where(cols <= rows, s, NEG)
        else:
            if direction > 0:  # super-diagonal: drop bottom-left quadrant
                s = jnp.where((rows >= half) & (cols < half), NEG, s)
            else:  # sub-diagonal: drop top-right quadrant
                s = jnp.where((rows < half) & (cols >= half), NEG, s)
        # neighbour-block existence (block index is clamped in the spec,
        # so out-of-range neighbours alias a real block and must be cut)
        if direction < 0:
            s = jnp.where(i >= 1, s, NEG)
        elif direction > 0:
            s = jnp.where(i <= nb - 2, s, NEG)
        # key validity: zero fine-token count under a coarse key = padding
        s = jnp.where((c > 0)[None, :], s, NEG)
        scores.append(s)

    m = functools.reduce(jnp.maximum, [s.max(axis=1) for s in scores])
    m = jnp.maximum(m, NEG / 2)

    y = jnp.zeros((nr, d), jnp.float32)
    den = jnp.zeros((nr,), jnp.float32)
    for s, v_ref, c_ref in zip(scores, v_refs, c_refs):
        w = jnp.exp(s - m[:, None])
        y = y + jnp.dot(w, v_ref[0], preferred_element_type=jnp.float32)
        den = den + jnp.dot(w, c_ref[0], preferred_element_type=jnp.float32)

    y_ref[0] = y
    den_ref[0] = den
    m_ref[0] = m


def banded_block_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    counts: jnp.ndarray,
    nr: int,
    level: int,
    causal: bool,
):
    """One hierarchy level of banded block attention via Pallas.

    Args:
      q, k, v: [B, H, Lc, d] (k masked-averaged, v pair-summed upstream).
      counts: [B, Lc] valid-token counts under each coarse position.
      nr: block size, level: hierarchy level (0 = finest), causal: decoder.

    Returns:
      (y, den, m): [B,H,Lc,d], [B,H,Lc], [B,H,Lc] — the same LevelResult
      triple the jnp path produces.
    """
    b, h, lc, d = q.shape
    nb = lc // nr
    bh = b * h

    qf = q.reshape(bh, lc, d)
    kf = k.reshape(bh, lc, d)
    vf = v.reshape(bh, lc, d)
    cf = jnp.broadcast_to(counts[:, None, :], (b, h, lc)).reshape(bh, lc)

    dirs = _directions(level, causal)

    def qi(s, i):
        return (s, i, 0)

    def k_spec(direction):
        def idx(s, i):
            j = jnp.clip(i + direction, 0, nb - 1)
            return (s, j, 0)

        return pl.BlockSpec((1, nr, d), idx)

    def c_spec(direction):
        def idx(s, i):
            j = jnp.clip(i + direction, 0, nb - 1)
            return (s, j)

        return pl.BlockSpec((1, nr), idx)

    in_specs = [pl.BlockSpec((1, nr, d), qi)]
    args = [qf]
    for direction in dirs:
        in_specs.append(k_spec(direction))
        args.append(kf)
    for direction in dirs:
        in_specs.append(k_spec(direction))
        args.append(vf)
    for direction in dirs:
        in_specs.append(c_spec(direction))
        args.append(cf)

    out_specs = [
        pl.BlockSpec((1, nr, d), qi),
        pl.BlockSpec((1, nr), lambda s, i: (s, i)),
        pl.BlockSpec((1, nr), lambda s, i: (s, i)),
    ]
    out_shape = [
        jax.ShapeDtypeStruct((bh, lc, d), jnp.float32),
        jax.ShapeDtypeStruct((bh, lc), jnp.float32),
        jax.ShapeDtypeStruct((bh, lc), jnp.float32),
    ]

    kernel = functools.partial(
        _band_kernel, nr=nr, d=d, nb=nb, level=level, causal=causal
    )
    y, den, m = pl.pallas_call(
        kernel,
        grid=(bh, nb),
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=True,
    )(*args)

    return (
        y.reshape(b, h, lc, d),
        den.reshape(b, h, lc),
        m.reshape(b, h, lc),
    )
