"""Pure-numpy float64 oracles for the hierarchical attention.

Two independent reference implementations used by the test suite:

* :func:`full_attention_ref` — the standard O(L^2) softmax attention
  (paper Eq. 1), the ground truth that h1d *approximates*.
* :func:`h1d_attention_ref` — the hierarchical attention computed the
  *slow, explicit* way: the approximate attention matrix of paper
  Eq. (55)-(57) is materialised at fine resolution (coarse blocks
  expanded by the T^(l) expansion operators of Appendix A.3/A.4, i.e.
  piecewise-constant kron with a ones block), then normalised.  This is
  O(L^2) time/memory but shares no code with the fast blocked
  implementation in hattention.py, so agreement between the two is a
  strong correctness signal.

Everything here is numpy/float64 — deliberately a different numerical
stack from the jax/float32 production path.
"""

from __future__ import annotations

import math

import numpy as np


def _padded_length(seq_len: int, nr: int) -> int:
    nb = max(1, -(-seq_len // nr))
    nb_pow2 = 1 << (nb - 1).bit_length()
    return nr * nb_pow2


def _num_levels(lp: int, nr: int) -> int:
    nb = lp // nr
    return max(1, int(math.log2(nb)) + 1) if nb > 1 else 1


def _allowed(lc: int, nr: int, level: int, causal: bool) -> np.ndarray:
    """Boolean [lc, lc] matrix of entries this level is responsible for."""
    a = np.arange(lc)
    bi = (a // nr)[:, None]
    bj = (a // nr)[None, :]
    rloc = (a % nr)[:, None]
    cloc = (a % nr)[None, :]
    half = nr // 2
    if level == 0:
        if causal:
            return (bj == bi - 1) | ((bj == bi) & (a[None, :] <= a[:, None]))
        return np.abs(bi - bj) <= 1
    # Coarse level: super/sub-diagonal blocks minus the quadrant already
    # covered by the finer level (paper footnote 4).
    sup = (bj == bi + 1) & ~((rloc >= half) & (cloc < half))
    sub = (bj == bi - 1) & ~((rloc < half) & (cloc >= half))
    return sub if causal else (sub | sup)


def h1d_weight_matrix(
    q: np.ndarray,
    k: np.ndarray,
    nr: int,
    causal: bool = False,
    mask: np.ndarray | None = None,
) -> np.ndarray:
    """Explicit fine-resolution unnormalised weight matrix W ~ A of Eq. 16.

    q, k: [B, H, L, d].  Returns [B, H, Lp, Lp] with Lp the padded length.
    Entry (i, j) holds exp(S~) of whichever level covers (i, j) —
    expanded piecewise-constantly for coarse levels — and 0 for padding.
    """
    q = np.asarray(q, np.float64)
    k = np.asarray(k, np.float64)
    b, h, l, d = q.shape
    lp = _padded_length(l, nr)
    if mask is None:
        mask = np.ones((b, l))
    mask = np.asarray(mask, np.float64)
    if lp != l:
        pad = lp - l
        q = np.pad(q, ((0, 0), (0, 0), (0, pad), (0, 0)))
        k = np.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        mask = np.pad(mask, ((0, 0), (0, pad)))

    levels = _num_levels(lp, nr)
    scale = 1.0 / math.sqrt(d)

    w = np.zeros((b, h, lp, lp))
    qc = q
    ksum = k * mask[:, None, :, None]
    counts = mask.copy()
    for level in range(levels):
        if level > 0:
            bb, hh, lc, dd = qc.shape
            qc = qc.reshape(bb, hh, lc // 2, 2, dd).mean(axis=3)
            ksum = ksum.reshape(bb, hh, lc // 2, 2, dd).sum(axis=3)
            counts = counts.reshape(bb, lc // 2, 2).sum(axis=2)
        kc = ksum / np.maximum(counts[:, None, :, None], 1.0)
        s = np.einsum("bhid,bhjd->bhij", qc, kc) * scale
        lc = qc.shape[2]
        allowed = _allowed(lc, nr, level, causal)
        allowed = allowed[None, None] & (counts[:, None, None, :] > 0)
        wc = np.exp(s) * allowed
        f = 1 << level
        w += np.repeat(np.repeat(wc, f, axis=2), f, axis=3)
    # zero out padded keys at fine resolution (redundant with the coarse
    # count masking for fully-padded groups, but exact for partial groups)
    w *= mask[:, None, None, :]
    return w


def h1d_attention_ref(
    q: np.ndarray,
    k: np.ndarray,
    v: np.ndarray,
    nr: int,
    causal: bool = False,
    mask: np.ndarray | None = None,
) -> np.ndarray:
    """Dense-constructed hierarchical attention output (float64)."""
    b, h, l, d = np.asarray(q).shape
    w = h1d_weight_matrix(q, k, nr, causal=causal, mask=mask)
    lp = w.shape[-1]
    v64 = np.asarray(v, np.float64)
    if lp != l:
        v64 = np.pad(v64, ((0, 0), (0, 0), (0, lp - l), (0, 0)))
    num = np.einsum("bhij,bhjd->bhid", w, v64)
    den = w.sum(axis=-1, keepdims=True)
    z = num / np.maximum(den, 1e-300)
    return z[:, :, :l, :]


def full_attention_ref(
    q: np.ndarray,
    k: np.ndarray,
    v: np.ndarray,
    causal: bool = False,
    mask: np.ndarray | None = None,
) -> np.ndarray:
    """Standard softmax attention in float64 (paper Eq. 1)."""
    q = np.asarray(q, np.float64)
    k = np.asarray(k, np.float64)
    v = np.asarray(v, np.float64)
    b, h, l, d = q.shape
    s = np.einsum("bhid,bhjd->bhij", q, k) / math.sqrt(d)
    neg = -1e30
    if mask is not None:
        mask = np.asarray(mask, np.float64)
        s = s + np.where(mask[:, None, None, :] > 0, 0.0, neg)
    if causal:
        r = np.arange(l)
        s = s + np.where(r[:, None] >= r[None, :], 0.0, neg)[None, None]
    s = s - s.max(axis=-1, keepdims=True)
    w = np.exp(s)
    return np.einsum("bhij,bhjd->bhid", w / w.sum(axis=-1, keepdims=True), v)
