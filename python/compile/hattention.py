"""Layer-2: H-Transformer-1D hierarchical attention in JAX.

Implements the algorithm of Zhu & Soricut, "H-Transformer-1D: Fast
One-Dimensional Hierarchical Attention for Sequences" (ACL 2021):

* level-0: exact block-tridiagonal (encoder) / block-lower-bidiagonal
  (causal) attention with ``Nr x Nr`` blocks (paper Eq. 19/23);
* level-l (l >= 1): Q/K coarsened by pair-averaging, V by pair-summing
  (Eq. 25-27); only super- and sub-diagonal coarse blocks are scored
  (Eq. 21-22); the bottom-left quadrant of super-diagonal blocks and the
  top-right quadrant of sub-diagonal blocks are masked out because those
  interactions are already covered exactly by level l-1 (footnote 4);
* recombination: coarse partial numerators/denominators are interpolated
  back to fine resolution by row-duplication (Eq. 37-40, 69, 73) and summed.

The paper computes ``Z = D^{-1} A V`` with raw ``exp`` (Eq. 2-5).  We
compute exactly the same quantity but carry a per-row running max per
level and rescale when combining (log-sum-exp style), which is
float-safe and bit-equivalent in exact arithmetic.

Complexity: O(L * Nr * d) time and O(L * Nr) attention memory — linear in
the sequence length L (paper section 7).
"""

from __future__ import annotations

import functools
import math
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

NEG = -1e30  # additive mask value; exp(NEG - m) == 0 in f32 for any finite m


class LevelResult(NamedTuple):
    """Partial attention state produced by one hierarchy level.

    All tensors are at that level's (coarsened) resolution:
      y: [B, H, Lc, d]   unnormalised weighted value sums, scaled by exp(-m)
      den: [B, H, Lc]    unnormalised weight sums (the D of Eq. 5), same scale
      m: [B, H, Lc]      the per-row max logit used for the scaling
    """

    y: jnp.ndarray
    den: jnp.ndarray
    m: jnp.ndarray


def num_levels(seq_len: int, block_size: int) -> int:
    """Number of hierarchy levels M (paper Eq. 32): level 0 plus one coarse
    level per halving of the block count until fewer than 2 blocks remain."""
    if seq_len % block_size != 0:
        raise ValueError(f"seq_len {seq_len} not a multiple of Nr {block_size}")
    nb = seq_len // block_size
    if nb & (nb - 1) != 0:
        raise ValueError(f"block count {nb} must be a power of two")
    return max(1, int(math.log2(nb)) + 1) if nb > 1 else 1


def padded_length(seq_len: int, block_size: int) -> int:
    """Smallest L' >= seq_len with L' = Nr * 2^m (so the binary tree closes)."""
    nb = max(1, -(-seq_len // block_size))
    nb_pow2 = 1 << (nb - 1).bit_length()
    return block_size * nb_pow2


def _blockify(x: jnp.ndarray, nr: int) -> jnp.ndarray:
    """[B, H, L, d] -> [B, H, L/nr, nr, d]."""
    b, h, l, d = x.shape
    return x.reshape(b, h, l // nr, nr, d)


def _shift_blocks(xb: jnp.ndarray, direction: int) -> jnp.ndarray:
    """Shift along the block axis so slot i holds block i+direction.

    direction=-1: slot i holds block i-1 (left neighbour), block 0 zero.
    direction=+1: slot i holds block i+1 (right neighbour), last block zero.
    """
    if direction == 0:
        return xb
    zeros = jnp.zeros_like(xb[:, :, :1])
    if direction < 0:
        return jnp.concatenate([zeros, xb[:, :, :-1]], axis=2)
    return jnp.concatenate([xb[:, :, 1:], zeros], axis=2)


def _block_validity(nb: int, direction: int) -> jnp.ndarray:
    """[nb] 1.0 where the neighbour block in `direction` exists."""
    idx = jnp.arange(nb)
    if direction < 0:
        return (idx >= 1).astype(jnp.float32)
    if direction > 0:
        return (idx <= nb - 2).astype(jnp.float32)
    return jnp.ones((nb,), jnp.float32)


def _quadrant_mask(nr: int, direction: int) -> jnp.ndarray:
    """[nr, nr] additive mask removing the overlap quadrant (footnote 4).

    Super-diagonal (direction=+1): bottom-left quadrant already covered by
    the previous (finer) level.  Sub-diagonal (direction=-1): top-right.
    """
    r = jnp.arange(nr)[:, None]
    c = jnp.arange(nr)[None, :]
    half = nr // 2
    if direction > 0:
        covered = (r >= half) & (c < half)
    elif direction < 0:
        covered = (r < half) & (c >= half)
    else:
        return jnp.zeros((nr, nr), jnp.float32)
    return jnp.where(covered, NEG, 0.0)


def _causal_mask(nr: int) -> jnp.ndarray:
    """[nr, nr] additive mask: row attends to cols <= row (within a block)."""
    r = jnp.arange(nr)[:, None]
    c = jnp.arange(nr)[None, :]
    return jnp.where(c <= r, 0.0, NEG)


def _level_attention_fused(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    counts: jnp.ndarray,
    nr: int,
    level: int,
    causal: bool,
    dense: bool = False,
) -> LevelResult:
    """Fused variant of the per-level banded attention (§Perf, L2 pass).

    Instead of one einsum/exp/matmul triple per direction (2-3 of each),
    the neighbour key/value blocks are concatenated along the key axis so
    each level costs exactly one score einsum [nr, D*nr], one exp and two
    accumulation einsums — fewer, larger XLA ops (~25% faster end-to-end
    on the CPU PJRT runtime, see DESIGN.md's experiment index).

    Block-edge validity needs no explicit mask here: `_shift_blocks`
    fills out-of-range neighbours with zero counts, and the count==0 key
    mask removes them.
    """
    b, h, lc, d = q.shape
    nb = lc // nr
    scale = 1.0 / math.sqrt(d)

    qb = _blockify(q, nr)
    kb = _blockify(k, nr)
    vb = _blockify(v, nr)
    cb = counts.reshape(b, 1, nb, nr, 1)

    if causal:
        directions = (-1, 0) if level == 0 else (-1,)
    else:
        directions = (-1, 0, 1) if level == 0 else (-1, 1)

    kn = jnp.concatenate([_shift_blocks(kb, dd) for dd in directions], axis=3)
    vn = jnp.concatenate([_shift_blocks(vb, dd) for dd in directions], axis=3)

    s = jnp.einsum("bhnid,bhnjd->bhnij", qb, kn) * scale  # [B,H,nb,nr,D*nr]

    # static per-direction masks, concatenated along the key axis
    adds = []
    for dd in directions:
        if level == 0:
            if causal and dd == 0:
                adds.append(_causal_mask(nr))
            else:
                adds.append(jnp.zeros((nr, nr), jnp.float32))
        else:
            adds.append(_quadrant_mask(nr, dd))
    add = jnp.concatenate(adds, axis=1)  # [nr, D*nr]
    s = s + add[None, None, None]

    if dense:
        # no padding anywhere: key validity reduces to the static
        # neighbour-existence pattern per block, and every valid coarse
        # key covers exactly 2^level fine tokens (§Perf L2 pass: skips
        # the runtime count mask + the count-weighted denominator einsum)
        bv = jnp.concatenate(
            [jnp.broadcast_to(_block_validity(nb, dd)[:, None], (nb, nr)) for dd in directions],
            axis=1,
        )  # [nb, D*nr]
        s = s + jnp.where(bv > 0, 0.0, NEG)[None, None, :, None, :]
        m = jnp.maximum(s.max(axis=-1), NEG / 2)
        w = jnp.exp(s - m[..., None])
        y = jnp.einsum("bhnij,bhnjd->bhnid", w, vn)
        den = w.sum(axis=-1) * float(1 << level)
    else:
        cn = jnp.concatenate(
            [_shift_blocks(cb, dd) for dd in directions], axis=3
        )[:, :, :, :, 0]  # [B,1,nb,D*nr]
        kv = jnp.where(cn[:, :, :, None, :] > 0, 0.0, NEG)
        s = s + kv
        m = jnp.maximum(s.max(axis=-1), NEG / 2)
        w = jnp.exp(s - m[..., None])
        y = jnp.einsum("bhnij,bhnjd->bhnid", w, vn)
        den = jnp.einsum("bhnij,bcnj->bhni", w, cn)

    return LevelResult(
        y.reshape(b, h, lc, d), den.reshape(b, h, lc), m.reshape(b, h, lc)
    )


def _level_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    counts: jnp.ndarray,
    nr: int,
    level: int,
    causal: bool,
    use_pallas: bool = False,
    fused: bool = True,
    dense: bool = False,
) -> LevelResult:
    """Banded block attention at one hierarchy level (the L1 hot spot).

    q, k: [B, H, Lc, d] (k already masked-averaged), v: [B, H, Lc, d]
    (pair-summed), counts: [B, Lc] number of valid fine tokens under each
    coarse position (0 marks padding).  Level 0 passes counts in {0, 1}.

    Returns LevelResult at this level's resolution.
    """
    if use_pallas:
        from .kernels.hattn_pallas import banded_block_attention

        return LevelResult(*banded_block_attention(q, k, v, counts, nr, level, causal))

    if fused:
        return _level_attention_fused(q, k, v, counts, nr, level, causal, dense=dense)

    b, h, lc, d = q.shape
    nb = lc // nr
    scale = 1.0 / math.sqrt(d)

    qb = _blockify(q, nr)
    kb = _blockify(k, nr)
    vb = _blockify(v, nr)
    # counts as a [B, 1, nb, nr, 1] "value" so it can be block-shifted like V
    cb = counts.reshape(b, 1, nb, nr, 1)

    if causal:
        directions = (-1, 0) if level == 0 else (-1,)
    else:
        directions = (-1, 0, 1) if level == 0 else (-1, 1)

    score_list = []
    vals_list = []
    cnts_list = []
    for direction in directions:
        kn = _shift_blocks(kb, direction)
        vn = _shift_blocks(vb, direction)
        cn = _shift_blocks(cb, direction)[:, :, :, :, 0]  # [B,1,nb,nr(k)]
        s = jnp.einsum("bhnid,bhnjd->bhnij", qb, kn) * scale
        add = jnp.zeros((nr, nr), jnp.float32)
        if level == 0:
            if causal and direction == 0:
                add = add + _causal_mask(nr)
        else:
            add = add + _quadrant_mask(nr, direction)
        # neighbour-block existence + key validity (count == 0 -> padding)
        bv = _block_validity(nb, direction).reshape(1, 1, nb, 1, 1)
        kv = jnp.where(cn[:, :, :, None, :] > 0, 0.0, NEG)
        s = s + add[None, None, None] + jnp.where(bv > 0, 0.0, NEG) + kv
        score_list.append(s)
        vals_list.append(vn)
        cnts_list.append(cn)

    # Per-row max across all bands for the stable exp.
    m = functools.reduce(
        jnp.maximum, [s.max(axis=-1) for s in score_list]
    )  # [B,H,nb,nr]
    m = jnp.maximum(m, NEG / 2)  # fully-masked rows: keep exp args finite

    y = jnp.zeros((b, h, nb, nr, d), jnp.float32)
    den = jnp.zeros((b, h, nb, nr), jnp.float32)
    for s, vn, cn in zip(score_list, vals_list, cnts_list):
        w = jnp.exp(s - m[..., None])  # [B,H,nb,nr(q),nr(k)]
        y = y + jnp.einsum("bhnij,bhnjd->bhnid", w, vn)
        den = den + jnp.einsum("bhnij,bcnj->bhni", w, cn)

    return LevelResult(
        y.reshape(b, h, lc, d), den.reshape(b, h, lc), m.reshape(b, h, lc)
    )


def _coarsen(
    q: jnp.ndarray, ksum: jnp.ndarray, vsum: jnp.ndarray, counts: jnp.ndarray
):
    """One binary-tree coarsening step (paper Eq. 25-27).

    q is pair-averaged; ksum/vsum are pair-summed *masked* sums so that the
    coarse K can be formed as a masked average; counts pair-sum.
    """
    b, h, lc, d = q.shape
    q2 = q.reshape(b, h, lc // 2, 2, d).mean(axis=3)
    k2 = ksum.reshape(b, h, lc // 2, 2, d).sum(axis=3)
    v2 = vsum.reshape(b, h, lc // 2, 2, d).sum(axis=3)
    c2 = counts.reshape(b, lc // 2, 2).sum(axis=2)
    return q2, k2, v2, c2


def _interpolate(x: jnp.ndarray, factor: int, axis: int) -> jnp.ndarray:
    """Piecewise-constant interpolation P^(l) (Eq. 38-40): row duplication."""
    return jnp.repeat(x, factor, axis=axis)


def h1d_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    block_size: int = 16,
    causal: bool = False,
    mask: Optional[jnp.ndarray] = None,
    use_pallas: bool = False,
) -> jnp.ndarray:
    """Hierarchical 1D attention (the paper's Algorithm 1).

    Args:
      q, k, v: [B, H, L, d] float arrays.
      block_size: Nr, the numerical rank / level-0 block size (paper's only
        model hyper-parameter).  Must be even (quadrant masks) unless the
        sequence fits in one or two blocks.
      causal: decoder (lower-triangular) attention if True.
      mask: optional [B, L] validity mask (1 = real token, 0 = padding).
      use_pallas: route the per-level banded block attention through the
        Pallas L1 kernel (interpret mode) instead of plain jnp einsums.

    Returns:
      [B, H, L, d] attention output Z = D^{-1} A V with the hierarchical
      approximation of A.
    """
    b, h, l, d = q.shape
    nr = block_size
    lp = padded_length(l, nr)

    # dense fast path: no user mask and no padding => key validity is a
    # static pattern and counts are the constant 2^level (§Perf L2 pass)
    dense = mask is None and lp == l
    if mask is None:
        mask = jnp.ones((b, l), jnp.float32)
    mask = mask.astype(jnp.float32)

    if lp != l:
        pad = lp - l
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
        mask = jnp.pad(mask, ((0, 0), (0, pad)))

    nb0 = lp // nr
    levels = num_levels(lp, nr)
    if levels > 1 and nr % 2 != 0:
        raise ValueError("block_size must be even when coarse levels exist")

    mk = mask[:, None, :, None]
    ksum = k * mk  # masked K (numerator of the masked average)
    vsum = v * mk
    counts = mask  # [B, L]

    results = []
    qc, kc_sum, vc_sum, cc = q, ksum, vsum, counts
    for level in range(levels):
        if level > 0:
            qc, kc_sum, vc_sum, cc = _coarsen(qc, kc_sum, vc_sum, counts=cc)
        kc = kc_sum / jnp.maximum(cc[:, None, :, None], 1.0)
        results.append(
            _level_attention(
                qc, kc, vc_sum, cc, nr, level, causal,
                use_pallas=use_pallas, dense=dense,
            )
        )

    # Interpolate coarse partials to fine resolution and combine with a
    # shared per-fine-row rescale (exactly Eq. 69/73, but float-safe).
    y_f = []
    den_f = []
    m_f = []
    for level, res in enumerate(results):
        f = 1 << level
        y_f.append(_interpolate(res.y, f, axis=2))
        den_f.append(_interpolate(res.den, f, axis=2))
        m_f.append(_interpolate(res.m, f, axis=2))

    m_tot = functools.reduce(jnp.maximum, m_f)  # [B,H,L,]
    y = jnp.zeros_like(y_f[0])
    den = jnp.zeros_like(den_f[0])
    for yl, dl, ml in zip(y_f, den_f, m_f):
        w = jnp.exp(ml - m_tot)
        y = y + yl * w[..., None]
        den = den + dl * w
    z = y / jnp.maximum(den, 1e-30)[..., None]
    return z[:, :, :l, :]


def full_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = False,
    mask: Optional[jnp.ndarray] = None,
) -> jnp.ndarray:
    """Standard O(L^2) scaled dot-product attention (paper Eq. 1) — the
    quadratic baseline used throughout the benchmarks."""
    b, h, l, d = q.shape
    s = jnp.einsum("bhid,bhjd->bhij", q, k) / math.sqrt(d)
    if mask is not None:
        s = s + jnp.where(mask[:, None, None, :] > 0, 0.0, NEG)
    if causal:
        r = jnp.arange(l)
        causal_ok = r[:, None] >= r[None, :]  # query i attends keys j <= i
        s = s + jnp.where(causal_ok, 0.0, NEG)[None, None]
    s = s - s.max(axis=-1, keepdims=True)
    w = jnp.exp(s)
    den = w.sum(axis=-1, keepdims=True)
    return jnp.einsum("bhij,bhjd->bhid", w / jnp.maximum(den, 1e-30), v)
