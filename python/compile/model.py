"""Layer-2: Transformer models with pluggable (full | h1d) attention.

Pure-jax (no flax) so the whole train/eval/init surface lowers cleanly to
HLO text for the rust runtime.  The attention is a drop-in choice between
the quadratic baseline (paper Table 1/2 "Transformer baseline") and the
hierarchical attention of this paper — mirroring the paper's claim that
h1d is a drop-in replacement for the standard multi-head attention API.

Model zoo (driven by ModelConfig):
  * decoder LM (causal)         — One-Billion-Word experiments (Table 2)
  * encoder classifier          — LRA ListOps / Text / Image / Pathfinder
  * dual-encoder retrieval      — LRA Retrieval (two-document scoring)

Everything is deterministic (no dropout) so training is reproducible from
the seed artifact alone; the paper's experiments are about the attention
inductive bias, which is unaffected.
"""

from __future__ import annotations

import math
from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from . import hattention


class ModelConfig(NamedTuple):
    """Hyper-parameters for one model variant (recorded in the manifest)."""

    name: str = "model"
    vocab_size: int = 256
    d_model: int = 128
    n_heads: int = 4
    n_layers: int = 2
    d_ff: int = 512
    max_len: int = 512
    n_classes: int = 0  # 0 => language model head (tied embeddings)
    attention: str = "h1d"  # "full" | "h1d"
    block_size: int = 16  # Nr, the paper's single model hyper-parameter
    causal: bool = False
    dual_encoder: bool = False  # LRA Retrieval: encode two sequences
    use_pallas: bool = False  # route h1d through the L1 pallas kernel

    @property
    def d_head(self) -> int:
        return self.d_model // self.n_heads


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------

Params = Dict[str, jnp.ndarray]


def param_spec(cfg: ModelConfig) -> Dict[str, Tuple[int, ...]]:
    """Ordered name -> shape map; the canonical flattening used by the
    manifest and by the rust parameter store."""
    d, f, v = cfg.d_model, cfg.d_ff, cfg.vocab_size
    spec: Dict[str, Tuple[int, ...]] = {}
    spec["embed"] = (v, d)
    spec["pos"] = (cfg.max_len, d)
    for i in range(cfg.n_layers):
        p = f"layer{i:02d}."
        spec[p + "ln1_scale"] = (d,)
        spec[p + "ln1_bias"] = (d,)
        spec[p + "wq"] = (d, d)
        spec[p + "wk"] = (d, d)
        spec[p + "wv"] = (d, d)
        spec[p + "wo"] = (d, d)
        spec[p + "ln2_scale"] = (d,)
        spec[p + "ln2_bias"] = (d,)
        spec[p + "ff_w1"] = (d, f)
        spec[p + "ff_b1"] = (f,)
        spec[p + "ff_w2"] = (f, d)
        spec[p + "ff_b2"] = (d,)
    spec["ln_f_scale"] = (d,)
    spec["ln_f_bias"] = (d,)
    if cfg.n_classes > 0:
        feat = 4 * d if cfg.dual_encoder else d
        spec["cls_w1"] = (feat, d)
        spec["cls_b1"] = (d,)
        spec["cls_w2"] = (d, cfg.n_classes)
        spec["cls_b2"] = (cfg.n_classes,)
    return spec


def init_params(cfg: ModelConfig, seed: jnp.ndarray) -> Params:
    """Deterministic init from an int32 seed (exported as an artifact)."""
    key = jax.random.PRNGKey(seed.astype(jnp.uint32))
    spec = param_spec(cfg)
    keys = jax.random.split(key, len(spec))
    params: Params = {}
    for (name, shape), k in zip(spec.items(), keys):
        if name.endswith(("_bias", "_b1", "_b2")):
            params[name] = jnp.zeros(shape, jnp.float32)
        elif name.endswith("_scale"):
            params[name] = jnp.ones(shape, jnp.float32)
        elif name in ("embed", "pos"):
            params[name] = jax.random.normal(k, shape, jnp.float32) * 0.02
        else:
            std = 1.0 / math.sqrt(shape[0])
            params[name] = jax.random.normal(k, shape, jnp.float32) * std
    return params


def flatten_params(cfg: ModelConfig, params: Params):
    return [params[n] for n in param_spec(cfg)]


def unflatten_params(cfg: ModelConfig, flat) -> Params:
    names = list(param_spec(cfg))
    assert len(names) == len(flat)
    return dict(zip(names, flat))


# ---------------------------------------------------------------------------
# Forward pass
# ---------------------------------------------------------------------------


def _layer_norm(x, scale, bias, eps=1e-6):
    mu = x.mean(axis=-1, keepdims=True)
    var = ((x - mu) ** 2).mean(axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * scale + bias


def _attend(cfg: ModelConfig, q, k, v, mask):
    """Multi-head attention dispatch — the drop-in point the paper describes."""
    if cfg.attention == "full":
        return hattention.full_attention(q, k, v, causal=cfg.causal, mask=mask)
    if cfg.attention == "h1d":
        return hattention.h1d_attention(
            q,
            k,
            v,
            block_size=cfg.block_size,
            causal=cfg.causal,
            mask=mask,
            use_pallas=cfg.use_pallas,
        )
    raise ValueError(f"unknown attention {cfg.attention!r}")


def _split_heads(x, n_heads):
    b, l, d = x.shape
    return x.reshape(b, l, n_heads, d // n_heads).transpose(0, 2, 1, 3)


def _merge_heads(x):
    b, h, l, dh = x.shape
    return x.transpose(0, 2, 1, 3).reshape(b, l, h * dh)


def encode(
    cfg: ModelConfig,
    params: Params,
    tokens: jnp.ndarray,
    mask: Optional[jnp.ndarray] = None,
) -> jnp.ndarray:
    """Token ids [B, L] -> contextual features [B, L, D] (pre-head)."""
    b, l = tokens.shape
    x = params["embed"][tokens] + params["pos"][:l][None]
    if mask is not None:
        x = x * mask[:, :, None]
    for i in range(cfg.n_layers):
        p = f"layer{i:02d}."
        h = _layer_norm(x, params[p + "ln1_scale"], params[p + "ln1_bias"])
        q = _split_heads(h @ params[p + "wq"], cfg.n_heads)
        k = _split_heads(h @ params[p + "wk"], cfg.n_heads)
        v = _split_heads(h @ params[p + "wv"], cfg.n_heads)
        a = _attend(cfg, q, k, v, mask)
        x = x + _merge_heads(a) @ params[p + "wo"]
        h = _layer_norm(x, params[p + "ln2_scale"], params[p + "ln2_bias"])
        h = jax.nn.gelu(h @ params[p + "ff_w1"] + params[p + "ff_b1"])
        x = x + h @ params[p + "ff_w2"] + params[p + "ff_b2"]
    return _layer_norm(x, params["ln_f_scale"], params["ln_f_bias"])


def lm_logits(cfg: ModelConfig, params: Params, tokens: jnp.ndarray) -> jnp.ndarray:
    """Decoder LM: next-token logits via tied output embedding."""
    x = encode(cfg, params, tokens)
    return x @ params["embed"].T


def _masked_mean_pool(x, mask):
    if mask is None:
        return x.mean(axis=1)
    num = (x * mask[:, :, None]).sum(axis=1)
    den = jnp.maximum(mask.sum(axis=1, keepdims=True), 1.0)
    return num / den


def classifier_logits(
    cfg: ModelConfig,
    params: Params,
    tokens: jnp.ndarray,
    mask: Optional[jnp.ndarray] = None,
    tokens2: Optional[jnp.ndarray] = None,
    mask2: Optional[jnp.ndarray] = None,
) -> jnp.ndarray:
    """Encoder classifier (LRA).  Dual-encoder path for Retrieval uses the
    standard LRA feature combination [z1, z2, z1*z2, z1-z2]."""
    z1 = _masked_mean_pool(encode(cfg, params, tokens, mask), mask)
    if cfg.dual_encoder:
        assert tokens2 is not None
        z2 = _masked_mean_pool(encode(cfg, params, tokens2, mask2), mask2)
        feat = jnp.concatenate([z1, z2, z1 * z2, z1 - z2], axis=-1)
    else:
        feat = z1
    h = jax.nn.gelu(feat @ params["cls_w1"] + params["cls_b1"])
    return h @ params["cls_w2"] + params["cls_b2"]


# ---------------------------------------------------------------------------
# Losses
# ---------------------------------------------------------------------------


def lm_loss(cfg: ModelConfig, params: Params, tokens: jnp.ndarray) -> jnp.ndarray:
    """Mean next-token cross entropy over non-pad positions.

    tokens: [B, L] int32; position t predicts token t+1; id 0 is PAD and
    is excluded from the loss.
    """
    logits = lm_logits(cfg, params, tokens)  # [B, L, V]
    targets = tokens[:, 1:]
    logp = jax.nn.log_softmax(logits[:, :-1], axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    valid = (targets != 0).astype(jnp.float32)
    return (nll * valid).sum() / jnp.maximum(valid.sum(), 1.0)


def lm_eval_stats(cfg: ModelConfig, params: Params, tokens: jnp.ndarray):
    """(sum nll, token count) for exact corpus-level perplexity in rust."""
    logits = lm_logits(cfg, params, tokens)
    targets = tokens[:, 1:]
    logp = jax.nn.log_softmax(logits[:, :-1], axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    valid = (targets != 0).astype(jnp.float32)
    return (nll * valid).sum(), valid.sum()


def cls_loss(
    cfg: ModelConfig,
    params: Params,
    tokens,
    labels,
    mask=None,
    tokens2=None,
    mask2=None,
):
    logits = classifier_logits(cfg, params, tokens, mask, tokens2, mask2)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=-1)[:, 0]
    return nll.mean()


def cls_eval_stats(
    cfg: ModelConfig, params: Params, tokens, labels, mask=None, tokens2=None, mask2=None
):
    """(sum nll, correct count) over the batch."""
    logits = classifier_logits(cfg, params, tokens, mask, tokens2, mask2)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=-1)[:, 0]
    correct = (logits.argmax(axis=-1) == labels).astype(jnp.float32)
    return nll.sum(), correct.sum()


# ---------------------------------------------------------------------------
# Adam optimizer + train steps (exported as single fused HLO programs)
# ---------------------------------------------------------------------------

ADAM_B1 = 0.9
ADAM_B2 = 0.999
ADAM_EPS = 1e-8
GRAD_CLIP = 1.0


def adam_update(flat_params, flat_m, flat_v, grads, step, lr):
    """Adam with global-norm gradient clipping; step is 1-based int32."""
    gnorm = jnp.sqrt(sum(jnp.sum(g * g) for g in grads) + 1e-12)
    clip = jnp.minimum(1.0, GRAD_CLIP / gnorm)
    t = step.astype(jnp.float32)
    bc1 = 1.0 - ADAM_B1**t
    bc2 = 1.0 - ADAM_B2**t
    new_p, new_m, new_v = [], [], []
    for p, m, v, g in zip(flat_params, flat_m, flat_v, grads):
        g = g * clip
        m = ADAM_B1 * m + (1.0 - ADAM_B1) * g
        v = ADAM_B2 * v + (1.0 - ADAM_B2) * g * g
        mh = m / bc1
        vh = v / bc2
        p = p - lr * mh / (jnp.sqrt(vh) + ADAM_EPS)
        new_p.append(p)
        new_m.append(m)
        new_v.append(v)
    return new_p, new_m, new_v


def make_lm_train_step(cfg: ModelConfig):
    """Returns f(flat_params, flat_m, flat_v, step, lr, tokens) ->
    (flat_params', flat_m', flat_v', loss)."""

    def step_fn(flat_params, flat_m, flat_v, step, lr, tokens):
        def loss_fn(flat):
            return lm_loss(cfg, unflatten_params(cfg, flat), tokens)

        loss, grads = jax.value_and_grad(loss_fn)(list(flat_params))
        new_p, new_m, new_v = adam_update(flat_params, flat_m, flat_v, grads, step, lr)
        return new_p, new_m, new_v, loss

    return step_fn


def make_cls_train_step(cfg: ModelConfig):
    """Classifier train step; dual-encoder variants take a second sequence."""

    if cfg.dual_encoder:

        def step_fn(
            flat_params, flat_m, flat_v, step, lr, tokens, mask, labels, tokens2, mask2
        ):
            def loss_fn(flat):
                return cls_loss(
                    cfg, unflatten_params(cfg, flat), tokens, labels, mask, tokens2, mask2
                )

            loss, grads = jax.value_and_grad(loss_fn)(list(flat_params))
            new_p, new_m, new_v = adam_update(flat_params, flat_m, flat_v, grads, step, lr)
            return new_p, new_m, new_v, loss

    else:

        def step_fn(flat_params, flat_m, flat_v, step, lr, tokens, mask, labels):
            def loss_fn(flat):
                return cls_loss(cfg, unflatten_params(cfg, flat), tokens, labels, mask)

            loss, grads = jax.value_and_grad(loss_fn)(list(flat_params))
            new_p, new_m, new_v = adam_update(flat_params, flat_m, flat_v, grads, step, lr)
            return new_p, new_m, new_v, loss

    return step_fn


def count_params(cfg: ModelConfig) -> int:
    total = 0
    for shape in param_spec(cfg).values():
        n = 1
        for s in shape:
            n *= s
        total += n
    return total
