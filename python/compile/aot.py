"""AOT artifact emitter: lower every model/function to HLO *text*.

This is the single build-time bridge between python (L1+L2) and the rust
coordinator (L3).  Each jitted function is lowered to stablehlo, converted
to an XlaComputation and dumped as HLO text — NOT ``.serialize()``: jax
>= 0.5 emits HloModuleProto with 64-bit instruction ids which
xla_extension 0.5.1 (the version the published ``xla`` crate links)
rejects; the text parser reassigns ids and round-trips cleanly.

Outputs (under ``artifacts/``):
  * ``<model>.<fn>.hlo.txt``  — init / train / eval / fwd programs
  * ``attn_<variant>_L<len>.hlo.txt`` — attention-only microbench programs
  * ``manifest.json``         — every artifact's input/output signature,
    model configs and parameter layouts (parsed by rust/src/runtime).

Run via ``make artifacts`` (no-op when inputs are unchanged).
"""

from __future__ import annotations

import argparse
import json
import os
from typing import Any, Dict, List, Sequence

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import hattention, model as M

# ---------------------------------------------------------------------------
# Model zoo
# ---------------------------------------------------------------------------
# Scaled-down counterparts of the paper's experiments (see DESIGN.md §4 for
# the substitution table).  Every LRA task gets a quadratic baseline and an
# h1d variant with identical parameter counts; the LM gets an Nr ablation.

LRA_TASKS: Dict[str, Dict[str, Any]] = {
    # task -> generator-facing metadata + model dims
    "listops": dict(vocab=24, seq_len=512, classes=10, d=64, heads=2, layers=2, ff=256),
    "text": dict(vocab=256, seq_len=1024, classes=2, d=64, heads=2, layers=2, ff=256),
    "retrieval": dict(vocab=256, seq_len=512, classes=2, d=64, heads=2, layers=2, ff=256, dual=True),
    "image": dict(vocab=256, seq_len=1024, classes=10, d=64, heads=2, layers=2, ff=256),
    "pathfinder": dict(vocab=256, seq_len=1024, classes=2, d=64, heads=2, layers=2, ff=256),
}

LRA_BATCH = 16
LM_BATCH = 8

LM_VARIANTS: Dict[str, Dict[str, Any]] = {
    # Table 2 pair: identical dims, attention differs.
    "lm_tiny_h1d": dict(attention="h1d", nr=16, d=128, heads=4, layers=2, ff=512,
                        vocab=4096, seq_len=256),
    "lm_tiny_full": dict(attention="full", nr=16, d=128, heads=4, layers=2, ff=512,
                         vocab=4096, seq_len=256),
    # Nr ablation (paper: "We tried different Nr ... These represent
    # different inductive bias").
    "lm_tiny_nr4": dict(attention="h1d", nr=4, d=128, heads=4, layers=2, ff=512,
                        vocab=4096, seq_len=256),
    "lm_tiny_nr8": dict(attention="h1d", nr=8, d=128, heads=4, layers=2, ff=512,
                        vocab=4096, seq_len=256),
    "lm_tiny_nr32": dict(attention="h1d", nr=32, d=128, heads=4, layers=2, ff=512,
                         vocab=4096, seq_len=256),
    # Wider/deeper pair, the "144M vs 53M" axis of Table 2 scaled down.
    "lm_base_h1d": dict(attention="h1d", nr=16, d=256, heads=4, layers=4, ff=1024,
                        vocab=8192, seq_len=512),
    "lm_base_full": dict(attention="full", nr=16, d=256, heads=4, layers=4, ff=1024,
                         vocab=8192, seq_len=512),
}

# Attention-only microbench artifacts (scaling figure, §7 complexity):
ATTN_BENCH_LENS = [128, 256, 512, 1024, 2048, 4096]
ATTN_BENCH_SHAPE = dict(batch=1, heads=4, d_head=32, nr=16)


def _hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # CRITICAL: print with large constants included.  The default printer
    # elides big literals as "{...}" and the XLA 0.5.1 text parser on the
    # rust side silently reads those as ZEROS — corrupting any program
    # whose lowering constant-folded a mask/iota into a literal (we lost a
    # day's worth of debugging to a 0.56 max-abs output error from this).
    opts = xc._xla.HloPrintOptions()
    opts.print_large_constants = True
    # the 0.5.1 text parser rejects newer metadata attributes
    # (source_end_line etc.), so strip metadata entirely
    opts.print_metadata = False
    text = comp.get_hlo_module().to_string(opts)
    if "{...}" in text:
        raise RuntimeError("HLO text still contains elided constants")
    return text


def _dtype_str(dt) -> str:
    return {"float32": "f32", "int32": "i32", "uint32": "u32"}[str(dt)]


def _sig(avals: Sequence[Any]) -> List[Dict[str, Any]]:
    return [
        {"dtype": _dtype_str(a.dtype), "shape": [int(s) for s in a.shape]}
        for a in avals
    ]


class Emitter:
    def __init__(self, out_dir: str, only: str | None = None):
        self.out_dir = out_dir
        self.only = only
        self.manifest: Dict[str, Any] = {"version": 1, "models": {}, "attention": {}}
        os.makedirs(out_dir, exist_ok=True)

    def want(self, name: str) -> bool:
        return self.only is None or name.startswith(self.only)

    def emit(self, fname: str, fn, example_args) -> Dict[str, Any]:
        """Lower fn at the example arg shapes and write HLO text."""
        lowered = jax.jit(fn).lower(*example_args)
        text = _hlo_text(lowered)
        path = os.path.join(self.out_dir, fname)
        with open(path, "w") as f:
            f.write(text)
        out_avals = jax.tree_util.tree_leaves(
            jax.eval_shape(fn, *example_args)
        )
        flat_in = jax.tree_util.tree_leaves(example_args)
        print(f"  wrote {fname} ({len(text)} chars, {len(flat_in)} in / {len(out_avals)} out)")
        return {
            "file": fname,
            "inputs": _sig(flat_in),
            "outputs": _sig(out_avals),
        }


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def _param_specs(cfg: M.ModelConfig):
    return [_spec(s) for s in M.param_spec(cfg).values()]


def emit_model(em: Emitter, name: str, cfg: M.ModelConfig, task: str, batch: int):
    if not em.want(name):
        return
    print(f"model {name} (params={M.count_params(cfg):,})")
    pspecs = _param_specs(cfg)
    n_p = len(pspecs)
    entry: Dict[str, Any] = {
        "task": task,
        "batch": batch,
        "param_count": M.count_params(cfg),
        "config": {
            "vocab_size": cfg.vocab_size,
            "d_model": cfg.d_model,
            "n_heads": cfg.n_heads,
            "n_layers": cfg.n_layers,
            "d_ff": cfg.d_ff,
            "max_len": cfg.max_len,
            "n_classes": cfg.n_classes,
            "attention": cfg.attention,
            "block_size": cfg.block_size,
            "causal": cfg.causal,
            "dual_encoder": cfg.dual_encoder,
        },
        "params": [
            {"name": n, "shape": list(s)} for n, s in M.param_spec(cfg).items()
        ],
        "artifacts": {},
    }
    arts = entry["artifacts"]

    # init: seed -> params
    def init_fn(seed):
        return tuple(M.flatten_params(cfg, M.init_params(cfg, seed)))

    arts["init"] = em.emit(f"{name}.init.hlo.txt", init_fn, (_spec((), jnp.int32),))

    seq = cfg.max_len
    if cfg.n_classes == 0:
        tokens = _spec((batch, seq), jnp.int32)
        train = M.make_lm_train_step(cfg)

        def train_fn(*args):
            ps = list(args[:n_p])
            ms = list(args[n_p : 2 * n_p])
            vs = list(args[2 * n_p : 3 * n_p])
            step, lr, toks = args[3 * n_p : 3 * n_p + 3]
            np_, nm, nv, loss = train(ps, ms, vs, step, lr, toks)
            return tuple(np_) + tuple(nm) + tuple(nv) + (loss,)

        train_args = tuple(pspecs * 3) + (_spec((), jnp.int32), _spec((), jnp.float32), tokens)
        arts["train"] = em.emit(f"{name}.train.hlo.txt", train_fn, train_args)

        def eval_fn(*args):
            params = M.unflatten_params(cfg, list(args[:n_p]))
            return M.lm_eval_stats(cfg, params, args[n_p])

        arts["eval"] = em.emit(f"{name}.eval.hlo.txt", eval_fn, tuple(pspecs) + (tokens,))

        def fwd_fn(*args):
            params = M.unflatten_params(cfg, list(args[:n_p]))
            return (M.lm_logits(cfg, params, args[n_p]),)

        arts["fwd"] = em.emit(f"{name}.fwd.hlo.txt", fwd_fn, tuple(pspecs) + (tokens,))
    else:
        tokens = _spec((batch, seq), jnp.int32)
        fmask = _spec((batch, seq), jnp.float32)
        labels = _spec((batch,), jnp.int32)
        train = M.make_cls_train_step(cfg)
        if cfg.dual_encoder:
            extra = (tokens, fmask, labels, tokens, fmask)
        else:
            extra = (tokens, fmask, labels)

        def train_fn(*args):
            ps = list(args[:n_p])
            ms = list(args[n_p : 2 * n_p])
            vs = list(args[2 * n_p : 3 * n_p])
            rest = args[3 * n_p :]
            np_, nm, nv, loss = train(ps, ms, vs, *rest)
            return tuple(np_) + tuple(nm) + tuple(nv) + (loss,)

        train_args = tuple(pspecs * 3) + (_spec((), jnp.int32), _spec((), jnp.float32)) + extra
        arts["train"] = em.emit(f"{name}.train.hlo.txt", train_fn, train_args)

        def eval_fn(*args):
            params = M.unflatten_params(cfg, list(args[:n_p]))
            rest = args[n_p:]
            if cfg.dual_encoder:
                toks, msk, lab, toks2, msk2 = rest
                return M.cls_eval_stats(cfg, params, toks, lab, msk, toks2, msk2)
            toks, msk, lab = rest
            return M.cls_eval_stats(cfg, params, toks, lab, msk)

        arts["eval"] = em.emit(f"{name}.eval.hlo.txt", eval_fn, tuple(pspecs) + extra)

        def fwd_fn(*args):
            params = M.unflatten_params(cfg, list(args[:n_p]))
            rest = args[n_p:]
            if cfg.dual_encoder:
                toks, msk, toks2, msk2 = rest
                return (M.classifier_logits(cfg, params, toks, msk, toks2, msk2),)
            toks, msk = rest
            return (M.classifier_logits(cfg, params, toks, msk),)

        fwd_extra = (tokens, fmask, tokens, fmask) if cfg.dual_encoder else (tokens, fmask)
        arts["fwd"] = em.emit(f"{name}.fwd.hlo.txt", fwd_fn, tuple(pspecs) + fwd_extra)

    em.manifest["models"][name] = entry


def emit_attention_benches(em: Emitter):
    """Attention-only programs for the §7 scaling experiment and the
    cross-language correctness check in examples/quickstart."""
    b = ATTN_BENCH_SHAPE["batch"]
    h = ATTN_BENCH_SHAPE["heads"]
    d = ATTN_BENCH_SHAPE["d_head"]
    nr = ATTN_BENCH_SHAPE["nr"]
    for length in ATTN_BENCH_LENS:
        spec = _spec((b, h, length, d))
        for variant in ("h1d", "full"):
            name = f"attn_{variant}_L{length}"
            if not em.want(name):
                continue

            if variant == "h1d":

                def fn(q, k, v):
                    return (hattention.h1d_attention(q, k, v, block_size=nr),)

            else:

                def fn(q, k, v):
                    return (hattention.full_attention(q, k, v),)

            info = em.emit(f"{name}.hlo.txt", fn, (spec, spec, spec))
            info.update(batch=b, heads=h, d_head=d, nr=nr, seq_len=length, variant=variant)
            em.manifest["attention"][name] = info
    # One pallas-routed artifact proving the L1 kernel composes end-to-end.
    name = "attn_h1d_pallas_L512"
    if em.want(name):
        spec = _spec((b, h, 512, d))

        def fn(q, k, v):
            return (hattention.h1d_attention(q, k, v, block_size=nr, use_pallas=True),)

        info = em.emit(f"{name}.hlo.txt", fn, (spec, spec, spec))
        info.update(batch=b, heads=h, d_head=d, nr=nr, seq_len=512, variant="h1d_pallas")
        em.manifest["attention"][name] = info


def build_model_zoo() -> Dict[str, M.ModelConfig]:
    zoo: Dict[str, M.ModelConfig] = {}
    for task, t in LRA_TASKS.items():
        for attn in ("h1d", "full"):
            name = f"lra_{task}_{attn}"
            zoo[name] = M.ModelConfig(
                name=name,
                vocab_size=t["vocab"],
                d_model=t["d"],
                n_heads=t["heads"],
                n_layers=t["layers"],
                d_ff=t["ff"],
                max_len=t["seq_len"],
                n_classes=t["classes"],
                attention=attn,
                block_size=16,
                causal=False,
                dual_encoder=bool(t.get("dual")),
            )
    for name, t in LM_VARIANTS.items():
        zoo[name] = M.ModelConfig(
            name=name,
            vocab_size=t["vocab"],
            d_model=t["d"],
            n_heads=t["heads"],
            n_layers=t["layers"],
            d_ff=t["ff"],
            max_len=t["seq_len"],
            n_classes=0,
            attention=t["attention"],
            block_size=t["nr"],
            causal=True,
        )
    return zoo


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="output directory")
    ap.add_argument("--only", default=None, help="emit only artifacts with this name prefix")
    ap.add_argument("--list", action="store_true", help="list model zoo and exit")
    args = ap.parse_args()

    zoo = build_model_zoo()
    if args.list:
        for name, cfg in zoo.items():
            print(f"{name}: {M.count_params(cfg):,} params, attn={cfg.attention}")
        return

    em = Emitter(args.out, only=args.only)
    for name, cfg in zoo.items():
        task = "lm" if cfg.n_classes == 0 else name.split("_")[1]
        batch = LM_BATCH if cfg.n_classes == 0 else LRA_BATCH
        emit_model(em, name, cfg, task, batch)
    emit_attention_benches(em)

    manifest_path = os.path.join(args.out, "manifest.json")
    # Merge with any existing manifest so --only runs don't clobber others.
    if args.only and os.path.exists(manifest_path):
        with open(manifest_path) as f:
            old = json.load(f)
        old.setdefault("models", {}).update(em.manifest["models"])
        old.setdefault("attention", {}).update(em.manifest["attention"])
        em.manifest = old
    with open(manifest_path, "w") as f:
        json.dump(em.manifest, f, indent=1, sort_keys=True)
    print(f"manifest: {manifest_path}")


if __name__ == "__main__":
    main()
