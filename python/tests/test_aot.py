"""AOT emitter tests: HLO text round-trips through the XLA parser, and
the manifest signature matches what the lowered program actually takes."""

import json
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import aot
from compile import model as M


def test_hlo_text_roundtrip_parses():
    """Emitted HLO text must be parseable back into an XlaComputation —
    the exact path the rust runtime uses (text -> proto -> compile)."""
    from jax._src.lib import xla_client as xc

    def fn(x):
        return (jnp.tanh(x) @ x.T,)

    spec = jax.ShapeDtypeStruct((4, 4), jnp.float32)
    lowered = jax.jit(fn).lower(spec)
    text = aot._hlo_text(lowered)
    assert "ENTRY" in text
    # parse back
    mod = xc._xla.hlo_module_from_text(text)
    assert mod is not None


def test_hlo_text_prints_large_constants():
    """Regression: the default HLO printer elides big literals as '{...}'
    which the rust-side XLA 0.5.1 text parser silently reads as ZEROS.
    Every emitted artifact must contain its constants verbatim."""

    def fn(x):
        # force a large folded constant (the h1d mask pattern)
        big = jnp.where(jnp.ones((8, 300)) > 0.5, 0.0, -1e30)
        return (x + big[:1, :2].sum(),)

    spec = jax.ShapeDtypeStruct((2,), jnp.float32)
    text = aot._hlo_text(jax.jit(fn).lower(spec))
    assert "{...}" not in text


def test_manifest_entry_matches_lowering(tmp_path):
    em = aot.Emitter(str(tmp_path))
    cfg = M.ModelConfig(
        name="t",
        vocab_size=32,
        d_model=8,
        n_heads=2,
        n_layers=1,
        d_ff=16,
        max_len=16,
        n_classes=0,
        attention="h1d",
        block_size=4,
        causal=True,
    )
    aot.emit_model(em, "t", cfg, "lm", 2)
    entry = em.manifest["models"]["t"]
    n_p = len(entry["params"])
    train = entry["artifacts"]["train"]
    # inputs: 3*params + step + lr + tokens
    assert len(train["inputs"]) == 3 * n_p + 3
    # outputs: 3*params + loss
    assert len(train["outputs"]) == 3 * n_p + 1
    assert train["inputs"][-1]["shape"] == [2, 16]
    assert train["outputs"][-1]["shape"] == []
    # files exist
    for art in entry["artifacts"].values():
        assert os.path.exists(tmp_path / art["file"])


def test_model_zoo_is_well_formed():
    zoo = aot.build_model_zoo()
    # every LRA task has a matched full/h1d pair with equal params
    for task in aot.LRA_TASKS:
        a = zoo[f"lra_{task}_h1d"]
        b = zoo[f"lra_{task}_full"]
        assert M.count_params(a) == M.count_params(b), task
        assert a.attention == "h1d" and b.attention == "full"
    # Table-2 pair matched too
    assert M.count_params(zoo["lm_tiny_h1d"]) == M.count_params(zoo["lm_tiny_full"])
    # Nr ablation shares the architecture
    for name in ("lm_tiny_nr4", "lm_tiny_nr8", "lm_tiny_nr32"):
        assert M.count_params(zoo[name]) == M.count_params(zoo["lm_tiny_h1d"])


def test_emitted_manifest_is_valid_json(tmp_path):
    em = aot.Emitter(str(tmp_path))
    aot.emit_attention_benches(em)
    path = tmp_path / "manifest.json"
    with open(path, "w") as f:
        json.dump(em.manifest, f)
    with open(path) as f:
        back = json.load(f)
    assert "attention" in back
    for name, entry in back["attention"].items():
        assert entry["file"].endswith(".hlo.txt"), name
        assert len(entry["inputs"]) == 3


def test_executed_init_matches_manifest_shapes(tmp_path):
    """Run the lowered init locally in jax and compare to manifest."""
    em = aot.Emitter(str(tmp_path))
    cfg = M.ModelConfig(
        name="t2", vocab_size=16, d_model=8, n_heads=2, n_layers=1,
        d_ff=16, max_len=8, n_classes=0, attention="full", block_size=4,
        causal=True,
    )
    aot.emit_model(em, "t2", cfg, "lm", 1)
    entry = em.manifest["models"]["t2"]
    params = M.init_params(cfg, jnp.int32(3))
    flat = M.flatten_params(cfg, params)
    for (meta, arr) in zip(entry["params"], flat):
        assert list(arr.shape) == meta["shape"], meta["name"]
