"""Correctness of the blocked jnp hierarchical attention vs the dense
numpy oracle, plus algebraic invariants.  This is the core L2 signal."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.hattention import (
    full_attention,
    h1d_attention,
    num_levels,
    padded_length,
)
from compile.kernels.ref import full_attention_ref, h1d_attention_ref

RNG = np.random.default_rng(0)


def rand(shape):
    return RNG.standard_normal(shape).astype(np.float32)


def run_h1d(q, k, v, nr, causal=False, mask=None, use_pallas=False):
    out = h1d_attention(
        jnp.asarray(q),
        jnp.asarray(k),
        jnp.asarray(v),
        block_size=nr,
        causal=causal,
        mask=None if mask is None else jnp.asarray(mask),
        use_pallas=use_pallas,
    )
    return np.asarray(out)


# ---------------------------------------------------------------------------
# fixed-case agreement with the oracle
# ---------------------------------------------------------------------------

CASES = [
    # (B, H, L, d, nr, causal)
    (2, 2, 32, 8, 4, False),
    (2, 2, 32, 8, 4, True),
    (1, 1, 64, 16, 8, False),
    (1, 1, 64, 16, 8, True),
    (1, 2, 48, 8, 4, False),   # padding: 48 -> 64
    (1, 1, 100, 8, 4, True),   # padding: 100 -> 128
    (1, 1, 16, 8, 8, False),   # exactly two blocks: no coarse level
    (2, 1, 8, 4, 8, True),     # single block
    (1, 1, 256, 8, 2, False),  # deep hierarchy (7 levels)
]


@pytest.mark.parametrize("b,h,l,d,nr,causal", CASES)
def test_blocked_matches_dense_oracle(b, h, l, d, nr, causal):
    q, k, v = rand((b, h, l, d)), rand((b, h, l, d)), rand((b, h, l, d))
    z = run_h1d(q, k, v, nr, causal)
    zr = h1d_attention_ref(q, k, v, nr, causal=causal)
    np.testing.assert_allclose(z, zr, rtol=2e-4, atol=2e-5)


@pytest.mark.parametrize("b,h,l,d,nr,causal", [c for c in CASES if c[2] <= 2 * c[4]])
def test_exact_when_band_covers_sequence(b, h, l, d, nr, causal):
    """L <= 2*Nr: the tridiagonal band covers everything => h1d == full."""
    q, k, v = rand((b, h, l, d)), rand((b, h, l, d)), rand((b, h, l, d))
    z = run_h1d(q, k, v, nr, causal)
    zf = full_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(z, zf, rtol=2e-4, atol=2e-5)


def test_full_attention_matches_numpy_ref():
    q, k, v = rand((2, 2, 24, 8)), rand((2, 2, 24, 8)), rand((2, 2, 24, 8))
    for causal in (False, True):
        z = np.asarray(
            full_attention(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v), causal=causal)
        )
        zr = full_attention_ref(q, k, v, causal=causal)
        np.testing.assert_allclose(z, zr, rtol=2e-4, atol=2e-5)


# ---------------------------------------------------------------------------
# invariants
# ---------------------------------------------------------------------------


def test_rows_are_normalised():
    """With V = ones the output must be exactly ones (weights sum to 1)."""
    q, k = rand((1, 2, 64, 8)), rand((1, 2, 64, 8))
    v = np.ones((1, 2, 64, 8), np.float32)
    for causal in (False, True):
        z = run_h1d(q, k, v, 8, causal)
        np.testing.assert_allclose(z, 1.0, rtol=1e-5, atol=1e-5)


def test_causal_is_independent_of_future():
    q = rand((1, 1, 64, 8))
    k1, v1 = rand((1, 1, 64, 8)), rand((1, 1, 64, 8))
    k2, v2 = k1.copy(), v1.copy()
    k2[:, :, 48:, :] += 7.0
    v2[:, :, 48:, :] -= 3.0
    z1 = run_h1d(q, k1, v1, 8, causal=True)
    z2 = run_h1d(q, k2, v2, 8, causal=True)
    np.testing.assert_array_equal(z1[:, :, :48], z2[:, :, :48])


def test_mask_excludes_padded_keys():
    """Output for valid rows must match the oracle under the same mask."""
    b, h, l, d, nr = 1, 1, 64, 8, 8
    q, k, v = rand((b, h, l, d)), rand((b, h, l, d)), rand((b, h, l, d))
    mask = np.ones((b, l), np.float32)
    mask[:, 40:] = 0.0
    z = run_h1d(q, k, v, nr, mask=mask)
    zr = h1d_attention_ref(q, k, v, nr, mask=mask)
    np.testing.assert_allclose(z[:, :, :40], zr[:, :, :40], rtol=2e-4, atol=2e-5)


def test_numerical_stability_large_logits():
    """Raw exp of Eq. 3 would overflow at scale 100; ours must not."""
    q = rand((1, 1, 32, 8)) * 100.0
    k = rand((1, 1, 32, 8)) * 100.0
    v = rand((1, 1, 32, 8))
    z = run_h1d(q, k, v, 4)
    assert np.isfinite(z).all()


def test_helpers():
    assert padded_length(100, 4) == 128
    assert padded_length(128, 4) == 128
    assert padded_length(3, 8) == 8
    assert num_levels(128, 4) == 6  # 32 blocks -> levels 0..5
    assert num_levels(8, 8) == 1
    with pytest.raises(ValueError):
        num_levels(100, 8)


# ---------------------------------------------------------------------------
# hypothesis sweeps
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(
    b=st.integers(1, 2),
    h=st.integers(1, 2),
    nr=st.sampled_from([2, 4, 8]),
    nblocks=st.integers(1, 9),
    d=st.sampled_from([4, 8, 16]),
    causal=st.booleans(),
    seed=st.integers(0, 2**31 - 1),
)
def test_hypothesis_blocked_vs_oracle(b, h, nr, nblocks, d, causal, seed):
    l = nr * nblocks
    rng = np.random.default_rng(seed)
    q = rng.standard_normal((b, h, l, d)).astype(np.float32)
    k = rng.standard_normal((b, h, l, d)).astype(np.float32)
    v = rng.standard_normal((b, h, l, d)).astype(np.float32)
    z = run_h1d(q, k, v, nr, causal)
    zr = h1d_attention_ref(q, k, v, nr, causal=causal)
    np.testing.assert_allclose(z, zr, rtol=3e-4, atol=3e-5)


@settings(max_examples=10, deadline=None)
@given(
    l=st.integers(3, 70),
    nr=st.sampled_from([4, 8]),
    seed=st.integers(0, 2**31 - 1),
)
def test_hypothesis_ragged_lengths_with_mask(l, nr, seed):
    rng = np.random.default_rng(seed)
    q = rng.standard_normal((1, 1, l, 4)).astype(np.float32)
    k = rng.standard_normal((1, 1, l, 4)).astype(np.float32)
    v = rng.standard_normal((1, 1, l, 4)).astype(np.float32)
    valid = max(1, l - (seed % l))
    mask = np.zeros((1, l), np.float32)
    mask[:, :valid] = 1.0
    z = run_h1d(q, k, v, nr, mask=mask)
    zr = h1d_attention_ref(q, k, v, nr, mask=mask)
    np.testing.assert_allclose(z[:, :, :valid], zr[:, :, :valid], rtol=3e-4, atol=3e-5)
