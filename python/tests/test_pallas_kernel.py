"""L1 Pallas kernel correctness: the banded block-attention kernel must
match both the jnp path and the dense oracle across shapes and levels."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.hattention import LevelResult, _level_attention, h1d_attention
from compile.kernels.hattn_pallas import banded_block_attention
from compile.kernels.ref import h1d_attention_ref

RNG = np.random.default_rng(1)


def rand(shape):
    return RNG.standard_normal(shape).astype(np.float32)


@pytest.mark.parametrize("causal", [False, True])
@pytest.mark.parametrize("level", [0, 1, 2])
def test_kernel_matches_jnp_level(level, causal):
    """The pallas kernel and the jnp einsum path compute the same
    LevelResult triple at every hierarchy level."""
    b, h, lc, d, nr = 2, 2, 32, 8, 4
    q, k, v = rand((b, h, lc, d)), rand((b, h, lc, d)), rand((b, h, lc, d))
    counts = np.full((b, lc), float(1 << level), np.float32)
    args = (jnp.asarray(q), jnp.asarray(k), jnp.asarray(v), jnp.asarray(counts))
    ref: LevelResult = _level_attention(*args, nr, level, causal, use_pallas=False)
    y, den, m = banded_block_attention(*args, nr, level, causal)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref.y), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(den), np.asarray(ref.den), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(m), np.asarray(ref.m), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize(
    "b,h,l,d,nr,causal",
    [
        (2, 2, 32, 8, 4, False),
        (2, 2, 32, 8, 4, True),
        (1, 1, 64, 16, 8, True),
        (1, 2, 48, 8, 4, False),  # ragged -> padded
    ],
)
def test_end_to_end_pallas_vs_oracle(b, h, l, d, nr, causal):
    q, k, v = rand((b, h, l, d)), rand((b, h, l, d)), rand((b, h, l, d))
    z = np.asarray(
        h1d_attention(
            jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
            block_size=nr, causal=causal, use_pallas=True,
        )
    )
    zr = h1d_attention_ref(q, k, v, nr, causal=causal)
    np.testing.assert_allclose(z, zr, rtol=2e-4, atol=2e-5)


def test_pallas_with_padding_mask():
    b, h, l, d, nr = 1, 1, 32, 8, 4
    q, k, v = rand((b, h, l, d)), rand((b, h, l, d)), rand((b, h, l, d))
    mask = np.ones((b, l), np.float32)
    mask[:, 20:] = 0.0
    z = np.asarray(
        h1d_attention(
            jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
            block_size=nr, mask=jnp.asarray(mask), use_pallas=True,
        )
    )
    zr = h1d_attention_ref(q, k, v, nr, mask=mask)
    np.testing.assert_allclose(z[:, :, :20], zr[:, :, :20], rtol=2e-4, atol=2e-5)


@settings(max_examples=10, deadline=None)
@given(
    nr=st.sampled_from([2, 4, 8]),
    nblocks=st.integers(1, 6),
    d=st.sampled_from([4, 8]),
    causal=st.booleans(),
    seed=st.integers(0, 2**31 - 1),
)
def test_hypothesis_pallas_vs_oracle(nr, nblocks, d, causal, seed):
    l = nr * nblocks
    rng = np.random.default_rng(seed)
    q = rng.standard_normal((1, 2, l, d)).astype(np.float32)
    k = rng.standard_normal((1, 2, l, d)).astype(np.float32)
    v = rng.standard_normal((1, 2, l, d)).astype(np.float32)
    z = np.asarray(
        h1d_attention(
            jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
            block_size=nr, causal=causal, use_pallas=True,
        )
    )
    zr = h1d_attention_ref(q, k, v, nr, causal=causal)
    np.testing.assert_allclose(z, zr, rtol=3e-4, atol=3e-5)
