"""The perf pass (indexed in DESIGN.md) added two specialised code paths
for the per-level banded attention: a fused-band variant and a dense
(no-padding) fast path.  All variants must be numerically equivalent."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.hattention import LevelResult, _level_attention

RNG = np.random.default_rng(3)


def rand(shape):
    return RNG.standard_normal(shape).astype(np.float32)


def run_variant(q, k, v, counts, nr, level, causal, **kw):
    r = _level_attention(
        jnp.asarray(q), jnp.asarray(k), jnp.asarray(v), jnp.asarray(counts),
        nr, level, causal, **kw
    )
    return tuple(np.asarray(x) for x in r)


@pytest.mark.parametrize("level", [0, 1])
@pytest.mark.parametrize("causal", [False, True])
def test_fused_equals_unfused(level, causal):
    b, h, lc, d, nr = 2, 2, 48, 8, 4
    q, k, v = rand((b, h, lc, d)), rand((b, h, lc, d)), rand((b, h, lc, d))
    counts = np.full((b, lc), float(1 << level), np.float32)
    a = run_variant(q, k, v, counts, nr, level, causal, fused=False)
    bb = run_variant(q, k, v, counts, nr, level, causal, fused=True)
    for x, y in zip(a, bb):
        np.testing.assert_allclose(x, y, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("level", [0, 1, 2])
@pytest.mark.parametrize("causal", [False, True])
def test_dense_equals_masked_with_full_counts(level, causal):
    b, h, lc, d, nr = 1, 2, 64, 8, 8
    q, k, v = rand((b, h, lc, d)), rand((b, h, lc, d)), rand((b, h, lc, d))
    counts = np.full((b, lc), float(1 << level), np.float32)
    a = run_variant(q, k, v, counts, nr, level, causal, fused=True, dense=False)
    bb = run_variant(q, k, v, counts, nr, level, causal, fused=True, dense=True)
    for x, y in zip(a, bb):
        np.testing.assert_allclose(x, y, rtol=1e-5, atol=1e-5)


def test_partial_counts_use_masked_path_semantics():
    """With padding (counts containing zeros) the masked path must zero
    those keys' contributions; the dense path is only legal for full
    counts — verify they differ exactly when padding exists."""
    b, h, lc, d, nr = 1, 1, 32, 4, 4
    q, k, v = rand((b, h, lc, d)), rand((b, h, lc, d)), rand((b, h, lc, d))
    counts = np.ones((b, lc), np.float32)
    counts[:, 24:] = 0.0
    masked = run_variant(q, k, v, counts, nr, 0, False, fused=True, dense=False)
    unfused = run_variant(q, k, v, counts, nr, 0, False, fused=False)
    for x, y in zip(masked, unfused):
        np.testing.assert_allclose(x, y, rtol=1e-5, atol=1e-5)


@settings(max_examples=15, deadline=None)
@given(
    nr=st.sampled_from([2, 4, 8]),
    nblocks=st.integers(2, 8),
    level=st.integers(0, 2),
    causal=st.booleans(),
    seed=st.integers(0, 2**31 - 1),
)
def test_hypothesis_fused_unfused_agree(nr, nblocks, level, causal, seed):
    rng = np.random.default_rng(seed)
    lc = nr * nblocks
    q = rng.standard_normal((1, 2, lc, 4)).astype(np.float32)
    k = rng.standard_normal((1, 2, lc, 4)).astype(np.float32)
    v = rng.standard_normal((1, 2, lc, 4)).astype(np.float32)
    counts = np.full((1, lc), float(1 << level), np.float32)
    a = run_variant(q, k, v, counts, nr, level, causal, fused=False)
    bb = run_variant(q, k, v, counts, nr, level, causal, fused=True)
    for x, y in zip(a, bb):
        np.testing.assert_allclose(x, y, rtol=2e-5, atol=2e-5)
