"""L2 model tests: shapes, parameter bookkeeping, loss semantics and a
few-step training sanity check (loss decreases) for both attentions."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import model as M


def tiny_cfg(**kw):
    base = dict(
        name="t",
        vocab_size=64,
        d_model=16,
        n_heads=2,
        n_layers=2,
        d_ff=32,
        max_len=32,
        n_classes=0,
        attention="h1d",
        block_size=4,
        causal=True,
    )
    base.update(kw)
    return M.ModelConfig(**base)


def test_param_spec_count_consistency():
    cfg = tiny_cfg()
    spec = M.param_spec(cfg)
    total = sum(int(np.prod(s)) for s in spec.values())
    assert total == M.count_params(cfg)
    params = M.init_params(cfg, jnp.int32(0))
    assert set(params.keys()) == set(spec.keys())
    for name, shape in spec.items():
        assert params[name].shape == shape, name


def test_flatten_roundtrip():
    cfg = tiny_cfg()
    params = M.init_params(cfg, jnp.int32(1))
    flat = M.flatten_params(cfg, params)
    back = M.unflatten_params(cfg, flat)
    for k in params:
        np.testing.assert_array_equal(np.asarray(params[k]), np.asarray(back[k]))


def test_init_deterministic_in_seed():
    cfg = tiny_cfg()
    p1 = M.init_params(cfg, jnp.int32(7))
    p2 = M.init_params(cfg, jnp.int32(7))
    p3 = M.init_params(cfg, jnp.int32(8))
    np.testing.assert_array_equal(np.asarray(p1["embed"]), np.asarray(p2["embed"]))
    assert np.abs(np.asarray(p1["embed"]) - np.asarray(p3["embed"])).max() > 0


@pytest.mark.parametrize("attention", ["full", "h1d"])
def test_lm_logits_shape_and_loss(attention):
    cfg = tiny_cfg(attention=attention)
    params = M.init_params(cfg, jnp.int32(0))
    tokens = jnp.ones((2, 32), jnp.int32) * 3
    logits = M.lm_logits(cfg, params, tokens)
    assert logits.shape == (2, 32, 64)
    loss = M.lm_loss(cfg, params, tokens)
    # random init => loss near ln(vocab)
    assert 2.0 < float(loss) < 8.0


def test_lm_loss_ignores_pad():
    cfg = tiny_cfg()
    params = M.init_params(cfg, jnp.int32(0))
    t1 = jnp.concatenate(
        [jnp.full((1, 16), 5, jnp.int32), jnp.zeros((1, 16), jnp.int32)], axis=1
    )
    l1 = M.lm_loss(cfg, params, t1)
    # changing content in the padded region must not change the loss...
    # except position 15->16 transition target; mutate only positions 17+
    t2 = t1.at[:, 17:].set(9)
    l2 = M.lm_loss(cfg, params, t1)  # same tokens => same loss
    assert float(l1) == float(l2)
    assert np.isfinite(float(M.lm_loss(cfg, params, t2)))


@pytest.mark.parametrize("dual", [False, True])
def test_classifier_shapes(dual):
    cfg = tiny_cfg(n_classes=5, causal=False, dual_encoder=dual)
    params = M.init_params(cfg, jnp.int32(0))
    tokens = jnp.ones((3, 32), jnp.int32)
    mask = jnp.ones((3, 32), jnp.float32)
    if dual:
        logits = M.classifier_logits(cfg, params, tokens, mask, tokens, mask)
    else:
        logits = M.classifier_logits(cfg, params, tokens, mask)
    assert logits.shape == (3, 5)
    labels = jnp.array([0, 3, 4], jnp.int32)
    if dual:
        loss = M.cls_loss(cfg, params, tokens, labels, mask, tokens, mask)
    else:
        loss = M.cls_loss(cfg, params, tokens, labels, mask)
    assert np.isfinite(float(loss))


def test_eval_stats_consistent_with_loss():
    cfg = tiny_cfg()
    params = M.init_params(cfg, jnp.int32(0))
    rng = np.random.default_rng(0)
    tokens = jnp.asarray(rng.integers(2, 64, size=(2, 32)), jnp.int32)
    loss = float(M.lm_loss(cfg, params, tokens))
    s, n = M.lm_eval_stats(cfg, params, tokens)
    assert abs(float(s) / float(n) - loss) < 1e-4


@pytest.mark.parametrize("attention", ["full", "h1d"])
def test_train_step_decreases_loss(attention):
    cfg = tiny_cfg(attention=attention)
    params = M.flatten_params(cfg, M.init_params(cfg, jnp.int32(0)))
    m = [jnp.zeros_like(p) for p in params]
    v = [jnp.zeros_like(p) for p in params]
    step_fn = jax.jit(M.make_lm_train_step(cfg))
    rng = np.random.default_rng(0)
    # one fixed batch: repeated steps must overfit it
    tokens = jnp.asarray(rng.integers(2, 64, size=(4, 32)), jnp.int32)
    losses = []
    for t in range(1, 21):
        params, m, v, loss = step_fn(
            params, m, v, jnp.int32(t), jnp.float32(3e-3), tokens
        )
        losses.append(float(loss))
    assert losses[-1] < losses[0] - 0.5, losses


def test_adam_bias_correction_first_step():
    # after one step with gradient g, update must be ~lr * sign-ish
    p = [jnp.array([1.0, -2.0])]
    m = [jnp.zeros(2)]
    v = [jnp.zeros(2)]
    g = [jnp.array([0.5, -0.5])]
    new_p, new_m, new_v = M.adam_update(p, m, v, g, jnp.int32(1), 0.1)
    # bias-corrected first step: m_hat = g, v_hat = g^2 => step = lr*sign(g)
    np.testing.assert_allclose(
        np.asarray(new_p[0]), np.array([1.0 - 0.1, -2.0 + 0.1]), rtol=1e-4
    )
    assert np.all(np.asarray(new_v[0]) > 0)
